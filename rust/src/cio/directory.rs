//! Cluster-wide retention directory: which IFS groups currently retain
//! each archive, and which retaining source a reader should pull from.
//!
//! PR 3's neighbor tier always asked the *producing* group — correct but
//! centralizing: on an all-to-all stage-2 read the producer of a popular
//! archive serves every cross-group fill while the groups that already
//! pulled copies sit idle. The paper's §5.3 intermediate tier has no such
//! constraint — any group holding a replica is an equally good source —
//! so [`RetentionDirectory`] tracks *all* retention locations, updated on
//! collector retains, neighbor-fill publishes, evictions, stage
//! re-run clears, and manifest warm starts, and
//! [`RetentionDirectory::route`] ranks the live sources for a reader by
//! torus hop distance ([`crate::cio::placement::group_torus_distance`]),
//! breaking ties toward the least-loaded source so concurrent fills of a
//! popular archive spread across its replicas instead of converging on
//! one hot owner.
//!
//! Entries are **hints, not truth**: a source can evict (or crash) in the
//! gap between a lookup and the pull. The read path in
//! [`crate::cio::local_stage::GroupCache::open_archive_via`] therefore
//! treats every candidate as fallible — a candidate whose retention turns
//! out to be gone is withdrawn ([`RetentionDirectory::record_stale`]) and
//! the resolve falls onward (next-nearest source → producing group →
//! GFS), so a stale entry only ever costs a fallback probe, never a wrong
//! read and never a wedged fill.
//!
//! Per-source serve counters ([`RetentionDirectory::serves`]) make the
//! load-spreading claim checkable: under the PR-3 producer-only policy
//! the producing group serves *every* cross-group fill of its archive;
//! with routing it must serve strictly fewer once a second replica
//! exists.
//!
//! **Liveness leases (PR 8).** The health ledger above learns about a
//! dead source one failed fill at a time — each discovery costs a reader
//! a blown deadline. A *lease* inverts that: a peer-lifecycle monitor
//! pings each serving peer on an interval and calls
//! [`RetentionDirectory::renew_lease`] on success; when
//! [`RetentionDirectory::expire_overdue`] finds a lease past its TTL it
//! withdraws **all** of that group's advertised retention in one sweep
//! (the same `record_stale` bookkeeping, batched) and bars the group from
//! routing *and* last-resort probes until the lease is renewed. A
//! hard-killed peer therefore stops being routed within one lease
//! interval, and after the sweep no reader burns a per-fill deadline
//! discovering the corpse. Groups without a lease (the common
//! shared-filesystem deployment) are unaffected — leases gate only the
//! groups that have ever held one.
//!
//! **Publish feed (PR 9).** Alongside the residency hints the directory
//! keeps an append-only feed of [`StreamEvent`]s: the producing
//! collector [`RetentionDirectory::announce`]s each archive the moment
//! it flushes (not at `finish()`), and a downstream stage
//! [`RetentionDirectory::subscribe`]s and consumes names with
//! [`RetentionDirectory::wait_for_prefix`] as they land. A
//! [`Subscription`] is a cursor into the log, so a late subscriber
//! replays already-announced archives instead of missing them. Each
//! stage prefix's stream carries a terminator — `end_stream` when the
//! upstream collector drains cleanly, `fail_stream` with a typed
//! [`FillError`] when it cannot — and every wait is timeout-bounded, so
//! no subscriber can wedge on a producer that died. A stage re-run
//! [`RetentionDirectory::retract`]s the purged names first, so a live
//! subscriber drops them instead of burning stale-fallback probes.

use crate::cio::fault::{FillError, RetryPolicy};
use crate::cio::placement::group_torus_distance;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Per-source circuit-breaker state (PR 6). A consecutive-failure streak
/// trips the quarantine; [`RetentionDirectory::note_fill_success`] fills
/// served *elsewhere* advance the probation clock until the source goes
/// half-open (eligible for one deliberate re-probe); a successful probe
/// recovers it fully, a failed one re-trips it.
#[derive(Default)]
struct SourceHealth {
    /// Consecutive failed probes (stale entries, IO errors, blown
    /// deadlines all count; any success resets it).
    streak: u32,
    /// Tripped: excluded from [`RetentionDirectory::route`] ranking
    /// until probation opens.
    quarantined: bool,
    /// Half-open: routed again (ranked first, as the deliberate probe)
    /// so one real fill decides recovery vs. re-trip.
    probation: bool,
    /// Successful fills served elsewhere since the trip.
    elsewhere: u32,
}

#[derive(Default)]
struct DirInner {
    /// archive name → groups currently retaining a copy.
    sources: BTreeMap<String, BTreeSet<u32>>,
    /// (archive name, source group) → neighbor fills served.
    serves: BTreeMap<(String, u32), u64>,
    /// source group → total neighbor fills served (route tie-breaker).
    group_serves: BTreeMap<u32, u64>,
    /// source group → transfers being served *right now* (the queue
    /// depth the load-aware route cost charges).
    inflight: BTreeMap<u32, u64>,
    /// Entries withdrawn because a pull found the retention gone.
    stale_withdrawals: u64,
    /// source group → circuit-breaker state.
    health: BTreeMap<u32, SourceHealth>,
    /// Total quarantine trips (re-trips from a failed probation probe
    /// included).
    quarantine_trips: u64,
    /// source group → when its liveness lease runs out.
    leases: BTreeMap<u32, Instant>,
    /// Groups whose lease expired and has not been renewed since —
    /// excluded from routing and probes absolutely.
    expired: BTreeSet<u32>,
    /// Total lease expirations (a flapping peer re-counts).
    lease_expirations: u64,
    /// Replica-loss events for the availability manager (PR 10). Only
    /// populated while `track_orphans` is set, so a runner without a
    /// repair daemon never accumulates an unbounded log.
    orphans: Vec<(String, OrphanCause)>,
    /// True once an [`crate::cio::repair::AvailabilityManager`] has
    /// subscribed to replica-loss events.
    track_orphans: bool,
}

impl DirInner {
    /// Charge one failed probe to `group`'s health; returns true when
    /// this event tripped (or re-tripped) the quarantine.
    fn charge_failure(&mut self, group: u32, streak_threshold: u32) -> bool {
        if streak_threshold == 0 {
            return false; // breaker disabled
        }
        let h = self.health.entry(group).or_default();
        h.streak += 1;
        let trip = if h.quarantined {
            // A failed probation probe re-trips the breaker and restarts
            // the probation clock.
            let retrip = h.probation;
            h.probation = false;
            if retrip {
                h.elsewhere = 0;
            }
            retrip
        } else {
            h.streak >= streak_threshold && {
                h.quarantined = true;
                h.probation = false;
                h.elsewhere = 0;
                true
            }
        };
        if trip {
            self.quarantine_trips += 1;
        }
        trip
    }

    /// Credit one successful fill: resets (and possibly recovers) the
    /// serving source, and advances every *other* quarantined source's
    /// probation clock.
    fn credit_success(&mut self, source: Option<u32>, probation_fills: u32) {
        if let Some(g) = source {
            if let Some(h) = self.health.get_mut(&g) {
                h.streak = 0;
                h.quarantined = false;
                h.probation = false;
                h.elsewhere = 0;
            }
        }
        for (&g, h) in self.health.iter_mut() {
            if Some(g) == source || !h.quarantined || h.probation {
                continue;
            }
            h.elsewhere += 1;
            if h.elsewhere >= probation_fills.max(1) {
                h.probation = true;
            }
        }
    }

    fn excluded(&self, group: u32) -> bool {
        self.expired.contains(&group)
            || self.health.get(&group).is_some_and(|h| h.quarantined && !h.probation)
    }

    /// Withdraw every retention entry `group` advertises, counting each
    /// as a stale withdrawal (the lease sweep is `record_stale` batched
    /// over a dead peer's whole advertisement). Archives left with *no*
    /// live source are logged as [`OrphanCause::PeerExpiry`] orphans for
    /// the availability manager.
    fn withdraw_all(&mut self, group: u32) -> u64 {
        let mut pulled = 0;
        let mut orphaned: Vec<String> = Vec::new();
        self.sources.retain(|name, set| {
            if set.remove(&group) {
                pulled += 1;
                if set.is_empty() {
                    orphaned.push(name.clone());
                }
            }
            !set.is_empty()
        });
        self.stale_withdrawals += pulled;
        if self.track_orphans {
            for name in orphaned {
                self.orphans.push((name, OrphanCause::PeerExpiry));
            }
        }
        pulled
    }

    fn on_probation(&self, group: u32) -> bool {
        self.health.get(&group).is_some_and(|h| h.quarantined && h.probation)
    }
}

/// Why an archive lost retention coverage (PR 10) — the event tag the
/// directory's replica-loss log carries so the
/// [`crate::cio::repair::AvailabilityManager`] can prioritize and count
/// repairs by cause.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OrphanCause {
    /// The archive's last live source's liveness lease expired
    /// ([`RetentionDirectory::expire_overdue`]): the bytes may still
    /// exist on the dead peer's IFS, but nothing routable serves them.
    PeerExpiry,
    /// Eviction (or a stage re-run clear) withdrew the archive's last
    /// listed replica.
    Eviction,
    /// A scrub pass found the copy rotted and dropped it
    /// ([`RetentionDirectory::record_scrub_drop`]); other replicas may
    /// survive, but the replica count just shrank and deserves an audit.
    ScrubDrop,
}

/// One entry in the directory's append-only publish feed (PR 9). The
/// feed is the *streaming* face of the directory: residency hints live
/// in the sources map, but the feed records the order in which archives
/// became visible, so a downstream stage can consume upstream output as
/// it lands instead of waiting for the producer's collector to drain.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StreamEvent {
    /// An archive landed on GFS (and usually in its producer's IFS):
    /// subscribers may open it now. Emitted once per flushed archive by
    /// the producing collector — re-publishes from neighbor fills do not
    /// re-announce.
    Announced { archive: String, group: u32 },
    /// The archive's bytes were purged (stage re-run clear): subscribers
    /// must drop it from their working set instead of burning a
    /// stale-fallback probe on a name that no longer resolves.
    Retracted { archive: String },
}

impl StreamEvent {
    fn archive(&self) -> &str {
        match self {
            StreamEvent::Announced { archive, .. } => archive,
            StreamEvent::Retracted { archive } => archive,
        }
    }
}

/// Termination state of one stage prefix's publish stream.
#[derive(Debug, Clone)]
enum StreamStatus {
    /// Producer still running: more announcements may arrive.
    Open,
    /// Producer's collector drained cleanly: the announced set is final.
    Ended,
    /// Producer failed (flush error / degraded group): waiters get the
    /// typed error instead of wedging on a stream that will never end.
    Failed(FillError),
}

#[derive(Default)]
struct FeedInner {
    /// Append-only event log; a [`Subscription`] is a cursor into it, so
    /// late subscribers replay everything already published.
    log: Vec<StreamEvent>,
    /// Archives currently announced and not retracted (dedup guard:
    /// announce/retract emit events only on state *changes*).
    live: BTreeSet<String>,
    /// stage prefix → stream termination state. Absent means open.
    streams: BTreeMap<String, StreamStatus>,
}

/// A cursor into the directory's publish feed. Created at generation 0,
/// so a subscriber that arrives after archives were already announced
/// replays them on its first [`RetentionDirectory::wait_for_prefix`]
/// call — late subscribers never miss an event.
#[derive(Debug, Default)]
pub struct Subscription {
    next: usize,
}

/// One batch of feed events delivered to a subscriber.
#[derive(Debug, Default)]
pub struct StreamBatch {
    /// Events that matched the requested prefixes, oldest first. Empty
    /// with `ended == false` means the wait timed out.
    pub events: Vec<StreamEvent>,
    /// True once every requested prefix's stream has ended *and* all
    /// prior events were delivered: no more events will ever arrive.
    pub ended: bool,
}

/// Does `archive` belong to stage `prefix`? Stage archives are named
/// `<prefix>-g<group>-<seq>.cioar`, and matching on the `-g` separator
/// keeps `s1` from claiming `s10-...`.
fn archive_in_prefix(archive: &str, prefix: &str) -> bool {
    archive.strip_prefix(prefix).is_some_and(|rest| rest.starts_with("-g"))
}

/// Cluster-wide (per-[`crate::cio::local::LocalLayout`]) registry of which
/// IFS groups retain which archives, with torus-distance source routing.
/// Shared by every [`crate::cio::local_stage::GroupCache`] of one runner;
/// all operations are internally synchronized (one short-held mutex, no
/// IO under it).
pub struct RetentionDirectory {
    groups: u32,
    quarantine_streak: u32,
    probation_fills: u32,
    inner: Mutex<DirInner>,
    feed: Mutex<FeedInner>,
    feed_cv: Condvar,
}

impl RetentionDirectory {
    /// An empty directory for a layout with `groups` IFS groups, with
    /// the default [`RetryPolicy`] quarantine thresholds.
    pub fn new(groups: u32) -> RetentionDirectory {
        let policy = RetryPolicy::default();
        RetentionDirectory::with_health(groups, policy.quarantine_streak, policy.probation_fills)
    }

    /// An empty directory with explicit circuit-breaker thresholds: a
    /// source is quarantined after `quarantine_streak` consecutive
    /// failures (0 disables the breaker) and goes half-open after
    /// `probation_fills` successful fills served elsewhere.
    pub fn with_health(
        groups: u32,
        quarantine_streak: u32,
        probation_fills: u32,
    ) -> RetentionDirectory {
        RetentionDirectory {
            groups: groups.max(1),
            quarantine_streak,
            probation_fills,
            inner: Mutex::new(DirInner::default()),
            feed: Mutex::new(FeedInner::default()),
            feed_cv: Condvar::new(),
        }
    }

    /// Number of IFS groups this directory routes over.
    pub fn groups(&self) -> u32 {
        self.groups
    }

    /// Record that `group` now retains `archive` (collector retain,
    /// neighbor-fill publish, GFS read-through, or manifest warm start).
    pub fn publish(&self, archive: &str, group: u32) {
        let mut inner = self.inner.lock().unwrap();
        inner.sources.entry(archive.to_string()).or_default().insert(group);
    }

    /// Record that `group` no longer retains `archive` (eviction or a
    /// stage re-run clear). Removing an unlisted pair is a no-op. When
    /// this withdrawal removes the archive's *last* listed replica, the
    /// loss is logged as an [`OrphanCause::Eviction`] orphan for the
    /// availability manager (which re-replicates it only if the archive's
    /// read history says it is still hot).
    pub fn withdraw(&self, archive: &str, group: u32) {
        let mut inner = self.inner.lock().unwrap();
        let mut emptied = false;
        if let Some(set) = inner.sources.get_mut(archive) {
            let removed = set.remove(&group);
            if set.is_empty() {
                inner.sources.remove(archive);
                emptied = removed;
            }
        }
        if emptied && inner.track_orphans {
            inner.orphans.push((archive.to_string(), OrphanCause::Eviction));
        }
    }

    /// Withdraw a copy a scrub pass found rotted and dropped, logging the
    /// loss as an [`OrphanCause::ScrubDrop`] orphan (when tracking is on)
    /// even while other replicas survive — the replica count shrank, so
    /// the availability manager should re-audit the archive's deficit.
    pub fn record_scrub_drop(&self, archive: &str, group: u32) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(set) = inner.sources.get_mut(archive) {
            set.remove(&group);
            if set.is_empty() {
                inner.sources.remove(archive);
            }
        }
        if inner.track_orphans {
            inner.orphans.push((archive.to_string(), OrphanCause::ScrubDrop));
        }
    }

    /// Start logging replica-loss events (idempotent). Called once by the
    /// [`crate::cio::repair::AvailabilityManager`] when it attaches;
    /// until then losses are not recorded, so a runner without a repair
    /// daemon pays nothing.
    pub fn enable_orphan_tracking(&self) {
        self.inner.lock().unwrap().track_orphans = true;
    }

    /// Drain the replica-loss log accumulated since the previous drain,
    /// oldest first. Empty unless
    /// [`RetentionDirectory::enable_orphan_tracking`] was called.
    pub fn drain_orphans(&self) -> Vec<(String, OrphanCause)> {
        std::mem::take(&mut self.inner.lock().unwrap().orphans)
    }

    /// Withdraw a candidate that a pull found stale (the retention was
    /// gone by the time the reader arrived) and count the event. The
    /// *cost* of staleness is the caller's fallback to the next source;
    /// the directory stops advertising the dead entry, and the event is
    /// folded into the source's health signal — enough stale probes trip
    /// the same quarantine an erroring source earns. Returns true when
    /// this event tripped the quarantine.
    pub fn record_stale(&self, archive: &str, group: u32) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if let Some(set) = inner.sources.get_mut(archive) {
            set.remove(&group);
            if set.is_empty() {
                inner.sources.remove(archive);
            }
        }
        inner.stale_withdrawals += 1;
        inner.charge_failure(group, self.quarantine_streak)
    }

    /// Charge one failed (or deadline-blown) probe of `group` to its
    /// health without withdrawing any retention entry — the copy may be
    /// fine; the *source* is misbehaving. Returns true when this event
    /// tripped the quarantine.
    pub fn record_failure(&self, group: u32) -> bool {
        self.inner.lock().unwrap().charge_failure(group, self.quarantine_streak)
    }

    /// Credit one successful fill: `Some(group)` for a neighbor/producer
    /// serve (resets its streak and recovers it if it was the probation
    /// probe), `None` for a GFS fill. Either way, every *other*
    /// quarantined source's probation clock advances — after
    /// `probation_fills` successful fills elsewhere it goes half-open
    /// and is routed again for its re-probe.
    pub fn note_fill_success(&self, source: Option<u32>) {
        self.inner.lock().unwrap().credit_success(source, self.probation_fills);
    }

    /// Is `group` currently tripped (excluded from routing)? Half-open
    /// probation counts as quarantined — the breaker has not recovered
    /// until a probe succeeds.
    pub fn is_quarantined(&self, group: u32) -> bool {
        let inner = self.inner.lock().unwrap();
        inner.health.get(&group).is_some_and(|h| h.quarantined)
    }

    /// May `group` be probed as a last-resort candidate right now? True
    /// unless the group is quarantined *and not yet on probation* — the
    /// producer-fallback gate: a freshly tripped producer stops eating a
    /// full deadline on every fill, but once its probation clock matures
    /// (enough successful fills elsewhere) it is probe-eligible again,
    /// so the breaker can still close through the fallback path. A group
    /// whose liveness lease has expired is never probe-eligible — there
    /// is no peer behind the address to answer — until a renewed lease
    /// revives it.
    pub fn probe_allowed(&self, group: u32) -> bool {
        !self.inner.lock().unwrap().excluded(group)
    }

    /// Groups currently quarantined (probation included), ascending.
    pub fn quarantined(&self) -> Vec<u32> {
        let inner = self.inner.lock().unwrap();
        inner.health.iter().filter(|(_, h)| h.quarantined).map(|(&g, _)| g).collect()
    }

    /// Total quarantine trips so far (failed probation probes re-count).
    pub fn quarantine_trips(&self) -> u64 {
        self.inner.lock().unwrap().quarantine_trips
    }

    /// How many stale entries pulls have withdrawn so far.
    pub fn stale_withdrawals(&self) -> u64 {
        self.inner.lock().unwrap().stale_withdrawals
    }

    /// Record a successful liveness probe of `group`: its lease now runs
    /// `ttl` from this instant, and an expired group is revived (its
    /// future publishes route again). Only groups that have ever held a
    /// lease are subject to expiry — calling this opts the group into
    /// the lease regime.
    pub fn renew_lease(&self, group: u32, ttl: Duration) {
        let mut inner = self.inner.lock().unwrap();
        inner.leases.insert(group, Instant::now() + ttl);
        inner.expired.remove(&group);
    }

    /// Sweep the lease table: every group whose lease is past due has
    /// **all** of its advertised retention withdrawn in one step (each
    /// entry counted as a stale withdrawal) and is barred from routing
    /// and last-resort probes until [`RetentionDirectory::renew_lease`]
    /// revives it. Returns the groups expired by *this* sweep.
    pub fn expire_overdue(&self) -> Vec<u32> {
        let now = Instant::now();
        let mut inner = self.inner.lock().unwrap();
        let overdue: Vec<u32> = inner
            .leases
            .iter()
            .filter(|(_, &deadline)| deadline < now)
            .map(|(&g, _)| g)
            .collect();
        for &g in &overdue {
            inner.leases.remove(&g);
            inner.expired.insert(g);
            inner.lease_expirations += 1;
            inner.withdraw_all(g);
        }
        overdue
    }

    /// Total liveness-lease expirations so far.
    pub fn lease_expirations(&self) -> u64 {
        self.inner.lock().unwrap().lease_expirations
    }

    /// Groups currently barred because their lease expired, ascending.
    pub fn expired_peers(&self) -> Vec<u32> {
        self.inner.lock().unwrap().expired.iter().copied().collect()
    }

    /// Groups currently listed as retaining `archive`, ascending.
    pub fn sources(&self, archive: &str) -> Vec<u32> {
        let inner = self.inner.lock().unwrap();
        inner.sources.get(archive).map(|s| s.iter().copied().collect()).unwrap_or_default()
    }

    /// Every listed archive with its retaining groups (tests and
    /// diagnostics; ascending by name).
    pub fn entries(&self) -> Vec<(String, Vec<u32>)> {
        let inner = self.inner.lock().unwrap();
        inner
            .sources
            .iter()
            .map(|(name, set)| (name.clone(), set.iter().copied().collect()))
            .collect()
    }

    /// Number of archives with at least one listed source.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().sources.len()
    }

    /// True when no archive is listed anywhere.
    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().sources.is_empty()
    }

    /// The fill resolve order for `reader`: every listed source of
    /// `archive` except `reader` itself, cheapest first by the
    /// **load-aware cost** `hops × (1 + inflight_serves)` — a
    /// near-but-busy replica ranks below a slightly-farther idle one, so
    /// concurrent fills of a popular archive stop piling onto the
    /// nearest source. Ties break toward the source that has served the
    /// fewest fills historically (spread), then by group index
    /// (determinism). With nothing in flight the cost degenerates to
    /// plain hop distance — the PR-4 ranking. The caller probes
    /// candidates in order and falls back producer → GFS when all of
    /// them turn out stale.
    ///
    /// Quarantined sources are excluded from the ranking while tripped.
    /// A source on half-open probation is routed again and ranked
    /// *first*: the next fill is its deliberate re-probe (one request
    /// decides recovery or re-trip; a failure only costs the usual
    /// fallback to the next candidate).
    pub fn route(&self, archive: &str, reader: u32) -> Vec<u32> {
        let inner = self.inner.lock().unwrap();
        let Some(set) = inner.sources.get(archive) else {
            return Vec::new();
        };
        let mut out: Vec<u32> = set
            .iter()
            .copied()
            .filter(|&g| g != reader && !inner.excluded(g))
            .collect();
        out.sort_by_key(|&g| {
            let hops = group_torus_distance(reader, g, self.groups) as u64;
            let inflight = inner.inflight.get(&g).copied().unwrap_or(0);
            (
                !inner.on_probation(g),
                hops.saturating_mul(1 + inflight),
                inner.group_serves.get(&g).copied().unwrap_or(0),
                g,
            )
        });
        out
    }

    /// Record that `group` started serving a transfer (fills the
    /// load-aware route cost charges). Pair with
    /// [`RetentionDirectory::end_serve`].
    pub fn begin_serve(&self, group: u32) {
        let mut inner = self.inner.lock().unwrap();
        *inner.inflight.entry(group).or_insert(0) += 1;
    }

    /// Record that `group` finished serving a transfer.
    pub fn end_serve(&self, group: u32) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(n) = inner.inflight.get_mut(&group) {
            *n = n.saturating_sub(1);
            if *n == 0 {
                inner.inflight.remove(&group);
            }
        }
    }

    /// Transfers `group` is serving right now.
    pub fn inflight_serves(&self, group: u32) -> u64 {
        self.inner.lock().unwrap().inflight.get(&group).copied().unwrap_or(0)
    }

    /// Count one neighbor fill of `archive` served by `source`.
    pub fn record_serve(&self, archive: &str, source: u32) {
        let mut inner = self.inner.lock().unwrap();
        *inner.serves.entry((archive.to_string(), source)).or_insert(0) += 1;
        *inner.group_serves.entry(source).or_insert(0) += 1;
    }

    /// Neighbor fills of `archive` served by `source` so far.
    pub fn serves(&self, archive: &str, source: u32) -> u64 {
        let inner = self.inner.lock().unwrap();
        inner.serves.get(&(archive.to_string(), source)).copied().unwrap_or(0)
    }

    /// Total neighbor fills of `archive` across all sources.
    pub fn archive_fills(&self, archive: &str) -> u64 {
        let inner = self.inner.lock().unwrap();
        inner
            .serves
            .iter()
            .filter(|((name, _), _)| name == archive)
            .map(|(_, &n)| n)
            .sum()
    }

    /// Total neighbor fills `source` has served across all archives.
    pub fn group_serves(&self, source: u32) -> u64 {
        self.inner.lock().unwrap().group_serves.get(&source).copied().unwrap_or(0)
    }

    // ---- publish feed (PR 9: subscribe-on-read streaming) ----

    /// Announce a freshly flushed archive to the publish feed. Called by
    /// the producing collector the moment the archive lands on GFS —
    /// *before* `finish()` — so subscribers see output as it flushes.
    /// Idempotent per live archive: re-announcing an archive that was
    /// not retracted since is a no-op, so retention re-publishes (routed
    /// fills, manifest warm starts) never duplicate feed entries.
    pub fn announce(&self, archive: &str, group: u32) {
        let mut feed = self.feed.lock().unwrap();
        if feed.live.insert(archive.to_string()) {
            feed.log.push(StreamEvent::Announced { archive: archive.to_string(), group });
            self.feed_cv.notify_all();
        }
    }

    /// Retract an announced archive from the publish feed (stage re-run
    /// clear): live subscribers receive a [`StreamEvent::Retracted`] and
    /// drop the name instead of probing purged bytes. A no-op for names
    /// never announced (or already retracted).
    pub fn retract(&self, archive: &str) {
        let mut feed = self.feed.lock().unwrap();
        if feed.live.remove(archive) {
            feed.log.push(StreamEvent::Retracted { archive: archive.to_string() });
            self.feed_cv.notify_all();
        }
    }

    /// Mark `prefix`'s stream open (stage start / re-run). Clears a
    /// previous run's `Ended`/`Failed` terminator so a re-subscribing
    /// downstream waits for the new run's output, and retracts any of the
    /// previous run's names still live under the prefix — a re-run
    /// produces the *same* archive names (sequence numbers restart), so a
    /// stale live entry would make the announce dedup swallow the new
    /// run's announcement.
    pub fn open_stream(&self, prefix: &str) {
        let mut feed = self.feed.lock().unwrap();
        let stale: Vec<String> = feed
            .live
            .iter()
            .filter(|n| archive_in_prefix(n, prefix))
            .cloned()
            .collect();
        for name in stale {
            feed.live.remove(&name);
            feed.log.push(StreamEvent::Retracted { archive: name });
        }
        feed.streams.insert(prefix.to_string(), StreamStatus::Open);
        self.feed_cv.notify_all();
    }

    /// Mark `prefix`'s stream cleanly ended: the producing collector
    /// drained, every archive of the stage has been announced, and no
    /// more will arrive. Wakes all subscribers. Does not override an
    /// earlier failure — a failed stream stays failed until re-opened.
    pub fn end_stream(&self, prefix: &str) {
        let mut feed = self.feed.lock().unwrap();
        let status = feed.streams.entry(prefix.to_string()).or_insert(StreamStatus::Open);
        if !matches!(status, StreamStatus::Failed(_)) {
            *status = StreamStatus::Ended;
        }
        self.feed_cv.notify_all();
    }

    /// Terminate `prefix`'s stream with a typed error (upstream flush
    /// failure or degraded group): every blocked subscriber wakes and
    /// gets `err` instead of wedging on announcements that will never
    /// come. The first failure wins; later calls are no-ops.
    pub fn fail_stream(&self, prefix: &str, err: FillError) {
        let mut feed = self.feed.lock().unwrap();
        let status = feed.streams.entry(prefix.to_string()).or_insert(StreamStatus::Open);
        if !matches!(status, StreamStatus::Failed(_)) {
            *status = StreamStatus::Failed(err);
        }
        self.feed_cv.notify_all();
    }

    /// A fresh cursor into the publish feed, positioned at generation 0:
    /// the first wait replays every event already logged, so subscribing
    /// after archives were announced loses nothing.
    pub fn subscribe(&self) -> Subscription {
        Subscription::default()
    }

    /// Wait (bounded by `timeout`) for feed events on one stage prefix.
    /// See [`RetentionDirectory::wait_for_prefixes`].
    pub fn wait_for_prefix(
        &self,
        sub: &mut Subscription,
        prefix: &str,
        timeout: Duration,
    ) -> std::result::Result<StreamBatch, FillError> {
        self.wait_for_prefixes(sub, &[prefix], timeout)
    }

    /// Wait (bounded by `timeout`) for feed events on any of `prefixes`,
    /// advancing `sub`'s cursor past everything scanned. Returns, in
    /// order of preference:
    ///
    /// - `Ok` with matching events (oldest first) as soon as any exist —
    ///   already-logged events return immediately, no wait;
    /// - `Err` with the typed terminator once any requested stream has
    ///   failed and all earlier events were delivered;
    /// - `Ok` with an empty batch and `ended == true` once *all*
    ///   requested streams have ended and the log is drained;
    /// - `Ok` with an empty batch and `ended == false` when `timeout`
    ///   elapses first — the caller re-arms its own deadline policy, so
    ///   no subscriber ever parks indefinitely.
    pub fn wait_for_prefixes(
        &self,
        sub: &mut Subscription,
        prefixes: &[&str],
        timeout: Duration,
    ) -> std::result::Result<StreamBatch, FillError> {
        let deadline = Instant::now() + timeout;
        let mut feed = self.feed.lock().unwrap();
        loop {
            let mut events = Vec::new();
            while sub.next < feed.log.len() {
                let ev = &feed.log[sub.next];
                sub.next += 1;
                if prefixes.iter().any(|p| archive_in_prefix(ev.archive(), p)) {
                    events.push(ev.clone());
                }
            }
            if !events.is_empty() {
                return Ok(StreamBatch { events, ended: false });
            }
            // Log drained: the stream state decides whether to report a
            // terminator or keep waiting.
            let failed = prefixes.iter().find_map(|p| match feed.streams.get(*p) {
                Some(StreamStatus::Failed(err)) => Some(err.clone()),
                _ => None,
            });
            if let Some(err) = failed {
                return Err(err);
            }
            let all_ended = prefixes
                .iter()
                .all(|p| matches!(feed.streams.get(*p), Some(StreamStatus::Ended)));
            if all_ended {
                return Ok(StreamBatch { events: Vec::new(), ended: true });
            }
            let now = Instant::now();
            if now >= deadline {
                return Ok(StreamBatch { events: Vec::new(), ended: false });
            }
            feed = self.feed_cv.wait_timeout(feed, deadline - now).unwrap().0;
        }
    }

    /// How many events the publish feed has logged so far (the feed's
    /// generation counter; tests and diagnostics).
    pub fn feed_generation(&self) -> usize {
        self.feed.lock().unwrap().log.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_withdraw_sources() {
        let d = RetentionDirectory::new(4);
        assert!(d.is_empty());
        d.publish("a.cioar", 0);
        d.publish("a.cioar", 2);
        d.publish("a.cioar", 2); // idempotent
        d.publish("b.cioar", 1);
        assert_eq!(d.sources("a.cioar"), vec![0, 2]);
        assert_eq!(d.sources("b.cioar"), vec![1]);
        assert_eq!(d.len(), 2);
        d.withdraw("a.cioar", 0);
        assert_eq!(d.sources("a.cioar"), vec![2]);
        d.withdraw("a.cioar", 2);
        assert!(d.sources("a.cioar").is_empty());
        assert_eq!(d.len(), 1, "empty source sets are dropped");
        d.withdraw("ghost.cioar", 3); // no-op
        assert_eq!(d.entries(), vec![("b.cioar".to_string(), vec![1])]);
    }

    #[test]
    fn route_orders_by_distance_then_load_then_index() {
        // 4 groups fit a [2,2,1] torus: from group 0, groups 1 and 2 are
        // 1 hop away, group 3 is 2 hops.
        let d = RetentionDirectory::new(4);
        for g in [1, 2, 3] {
            d.publish("a.cioar", g);
        }
        assert_eq!(d.route("a.cioar", 0), vec![1, 2, 3], "distance, then index");
        // Load the nearest source: the tie now breaks to the idle one.
        d.record_serve("a.cioar", 1);
        assert_eq!(d.route("a.cioar", 0), vec![2, 1, 3], "serve count breaks the tie");
        assert_eq!(d.serves("a.cioar", 1), 1);
        assert_eq!(d.group_serves(1), 1);
        assert_eq!(d.archive_fills("a.cioar"), 1);
        // The reader itself is never a candidate.
        d.publish("a.cioar", 0);
        assert!(!d.route("a.cioar", 0).contains(&0));
        // Unknown archives route nowhere.
        assert!(d.route("nope.cioar", 0).is_empty());
    }

    #[test]
    fn route_cost_is_load_aware() {
        // 4 groups on a [2,2,1] torus: from group 0, groups 1 and 2 are
        // equidistant (1 hop), group 3 is 2 hops.
        let d = RetentionDirectory::new(4);
        for g in [1, 2, 3] {
            d.publish("a.cioar", g);
        }
        // Skewed in-flight load on the equidistant pair: the idle one
        // must rank first — fills split instead of piling onto group 1.
        d.begin_serve(1);
        assert_eq!(d.inflight_serves(1), 1);
        assert_eq!(d.route("a.cioar", 0), vec![2, 1, 3], "busy equidistant source demoted");
        // hops x (1 + inflight): a near source with 2 transfers in
        // flight (cost 3) ranks below the 2-hop idle source (cost 2).
        d.begin_serve(1);
        d.begin_serve(2);
        d.begin_serve(2);
        assert_eq!(
            d.route("a.cioar", 0),
            vec![3, 1, 2],
            "near-but-busy replicas rank below the farther idle one"
        );
        // Draining the transfers restores the plain distance order.
        for _ in 0..2 {
            d.end_serve(1);
            d.end_serve(2);
        }
        assert_eq!(d.inflight_serves(1), 0);
        assert_eq!(d.route("a.cioar", 0), vec![1, 2, 3]);
        // end_serve never underflows.
        d.end_serve(1);
        assert_eq!(d.inflight_serves(1), 0);
    }

    #[test]
    fn stale_withdrawal_stops_advertising_and_counts() {
        let d = RetentionDirectory::new(2);
        d.publish("a.cioar", 1);
        assert_eq!(d.route("a.cioar", 0), vec![1]);
        d.record_stale("a.cioar", 1);
        assert!(d.route("a.cioar", 0).is_empty(), "stale entry must stop routing");
        assert_eq!(d.stale_withdrawals(), 1);
        // Counting a stale probe of an already-withdrawn entry still
        // counts the event (two readers can race the same dead source).
        d.record_stale("a.cioar", 1);
        assert_eq!(d.stale_withdrawals(), 2);
    }

    #[test]
    fn quarantine_trips_probates_and_recovers() {
        let d = RetentionDirectory::with_health(4, 3, 2);
        for g in [1, 2] {
            d.publish("a.cioar", g);
        }
        // Two failures are a streak, not a trip.
        assert!(!d.record_failure(1));
        assert!(!d.record_failure(1));
        assert!(!d.is_quarantined(1));
        // A success resets the streak...
        d.note_fill_success(Some(1));
        assert!(!d.record_failure(1));
        assert!(!d.record_failure(1));
        // ...and the third consecutive failure trips the breaker.
        assert!(d.record_failure(1), "third consecutive failure must trip");
        assert!(d.is_quarantined(1));
        assert_eq!(d.quarantined(), vec![1]);
        assert_eq!(d.quarantine_trips(), 1);
        assert_eq!(d.route("a.cioar", 0), vec![2], "tripped source leaves the ranking");
        // Two successful fills elsewhere open probation: the source is
        // routed again, ranked first as the deliberate re-probe.
        d.note_fill_success(Some(2));
        d.note_fill_success(None); // GFS fills count as "elsewhere" too
        assert!(d.is_quarantined(1), "probation is still quarantined");
        assert_eq!(d.route("a.cioar", 0), vec![1, 2], "probation probe ranks first");
        // A failed probe re-trips (and re-counts the trip)...
        assert!(d.record_failure(1));
        assert_eq!(d.quarantine_trips(), 2);
        assert_eq!(d.route("a.cioar", 0), vec![2]);
        // ...while a successful probe after the next probation recovers.
        d.note_fill_success(None);
        d.note_fill_success(None);
        assert_eq!(d.route("a.cioar", 0), vec![1, 2]);
        d.note_fill_success(Some(1));
        assert!(!d.is_quarantined(1));
        assert_eq!(d.route("a.cioar", 0), vec![1, 2], "recovered source ranks normally");
        assert_eq!(d.quarantine_trips(), 2, "recovery does not count a trip");
    }

    #[test]
    fn stale_probes_feed_the_same_health_signal() {
        let d = RetentionDirectory::with_health(2, 2, 1);
        d.publish("a.cioar", 1);
        assert!(!d.record_stale("a.cioar", 1));
        d.publish("a.cioar", 1);
        assert!(d.record_stale("a.cioar", 1), "stale probes count toward the streak");
        assert!(d.is_quarantined(1));
        // Disabled breaker (threshold 0) never trips.
        let open = RetentionDirectory::with_health(2, 0, 1);
        for _ in 0..10 {
            assert!(!open.record_failure(1));
        }
        assert!(!open.is_quarantined(1));
    }

    #[test]
    fn expired_lease_withdraws_everything_and_bars_probes() {
        let d = RetentionDirectory::new(4);
        d.publish("a.cioar", 1);
        d.publish("b.cioar", 1);
        d.publish("b.cioar", 2);
        // Group 2 never opts into the lease regime: unaffected throughout.
        d.renew_lease(1, Duration::from_millis(0));
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(d.expire_overdue(), vec![1], "overdue lease expires");
        assert_eq!(d.lease_expirations(), 1);
        assert_eq!(d.expired_peers(), vec![1]);
        assert!(d.sources("a.cioar").is_empty(), "all of group 1's entries withdrawn");
        assert_eq!(d.sources("b.cioar"), vec![2], "other groups' entries survive");
        assert_eq!(d.stale_withdrawals(), 2, "the sweep reuses the stale bookkeeping");
        assert!(!d.probe_allowed(1), "no last-resort probes at a dead address");
        assert!(d.probe_allowed(2));
        // Even a re-publish (e.g. a racing manifest load) does not route
        // the dead peer back in while the lease is expired.
        d.publish("a.cioar", 1);
        assert!(d.route("a.cioar", 0).is_empty());
        // Renewal revives it in one step.
        d.renew_lease(1, Duration::from_secs(60));
        assert!(d.probe_allowed(1));
        assert_eq!(d.route("a.cioar", 0), vec![1]);
        assert_eq!(d.expire_overdue(), Vec::<u32>::new(), "fresh lease does not expire");
    }

    #[test]
    fn orphan_log_records_last_replica_losses_by_cause() {
        let d = RetentionDirectory::new(4);
        d.publish("solo.cioar", 1);
        d.publish("dup.cioar", 1);
        d.publish("dup.cioar", 2);
        // Losses before tracking is enabled are not logged (no daemon,
        // no unbounded log).
        d.withdraw("solo.cioar", 1);
        d.enable_orphan_tracking();
        assert!(d.drain_orphans().is_empty());

        // Eviction: only the *last* replica's loss logs an orphan.
        d.publish("solo.cioar", 1);
        d.withdraw("dup.cioar", 2);
        d.withdraw("solo.cioar", 1);
        d.withdraw("never-listed.cioar", 3);
        assert_eq!(
            d.drain_orphans(),
            vec![("solo.cioar".to_string(), OrphanCause::Eviction)],
            "dup still has a source; unlisted names never orphan"
        );
        assert!(d.drain_orphans().is_empty(), "drain consumes the log");

        // Scrub drop logs even while a replica survives elsewhere.
        d.publish("dup.cioar", 2);
        d.record_scrub_drop("dup.cioar", 2);
        assert_eq!(d.sources("dup.cioar"), vec![1]);
        assert_eq!(d.drain_orphans(), vec![("dup.cioar".to_string(), OrphanCause::ScrubDrop)]);

        // A lease expiry orphans exactly the archives the dead peer was
        // the sole source of.
        d.publish("solo.cioar", 1);
        d.renew_lease(1, Duration::from_millis(0));
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(d.expire_overdue(), vec![1]);
        let orphans = d.drain_orphans();
        assert!(orphans.contains(&("solo.cioar".to_string(), OrphanCause::PeerExpiry)));
        assert!(orphans.contains(&("dup.cioar".to_string(), OrphanCause::PeerExpiry)));
        assert_eq!(orphans.len(), 2);
    }

    #[test]
    fn late_subscriber_replays_announced_archives() {
        let d = RetentionDirectory::new(2);
        d.open_stream("s0");
        d.announce("s0-g0-00000.cioar", 0);
        d.announce("s0-g1-00000.cioar", 1);
        d.announce("s0-g0-00000.cioar", 0); // re-announce dedups
        // A subscriber arriving after the fact replays both, in order.
        let mut sub = d.subscribe();
        let batch = d.wait_for_prefix(&mut sub, "s0", Duration::from_millis(0)).unwrap();
        assert_eq!(
            batch.events,
            vec![
                StreamEvent::Announced { archive: "s0-g0-00000.cioar".into(), group: 0 },
                StreamEvent::Announced { archive: "s0-g1-00000.cioar".into(), group: 1 },
            ]
        );
        assert!(!batch.ended);
        // Open stream + drained log: a zero-timeout wait returns empty.
        let idle = d.wait_for_prefix(&mut sub, "s0", Duration::from_millis(0)).unwrap();
        assert!(idle.events.is_empty() && !idle.ended);
        // End-of-stream is observed only after all events are consumed.
        d.end_stream("s0");
        let fin = d.wait_for_prefix(&mut sub, "s0", Duration::from_millis(0)).unwrap();
        assert!(fin.events.is_empty() && fin.ended);
    }

    #[test]
    fn prefix_match_does_not_cross_stage_names() {
        let d = RetentionDirectory::new(2);
        d.announce("s1-g0-00000.cioar", 0);
        d.announce("s10-g0-00000.cioar", 0);
        let mut sub = d.subscribe();
        let batch = d.wait_for_prefix(&mut sub, "s1", Duration::from_millis(0)).unwrap();
        assert_eq!(batch.events.len(), 1, "s1 must not claim s10's archives");
        assert_eq!(batch.events[0].archive(), "s1-g0-00000.cioar");
    }

    #[test]
    fn failed_stream_delivers_typed_error_after_pending_events() {
        let d = RetentionDirectory::new(2);
        d.open_stream("s0");
        d.announce("s0-g0-00000.cioar", 0);
        let err = FillError {
            tier: crate::cio::fault::FillTier::Staging,
            source: None,
            retryable: false,
            storage: true,
            timeout: false,
            corrupt: false,
            msg: "flush failed".to_string(),
        };
        d.fail_stream("s0", err);
        let mut sub = d.subscribe();
        // Events logged before the failure still arrive...
        let batch = d.wait_for_prefix(&mut sub, "s0", Duration::from_millis(0)).unwrap();
        assert_eq!(batch.events.len(), 1);
        // ...then the typed terminator, immediately (no timeout burn).
        let got = d.wait_for_prefix(&mut sub, "s0", Duration::from_secs(30)).unwrap_err();
        assert!(got.storage);
        // end_stream does not launder a failure...
        d.end_stream("s0");
        assert!(d.wait_for_prefix(&mut sub, "s0", Duration::from_millis(0)).is_err());
        // ...but a re-run's open_stream resets the terminator and
        // retracts the failed run's live names, so the re-run's identical
        // archive names can be re-announced past the dedup.
        d.open_stream("s0");
        let reset = d.wait_for_prefix(&mut sub, "s0", Duration::from_millis(0)).unwrap();
        assert_eq!(
            reset.events,
            vec![StreamEvent::Retracted { archive: "s0-g0-00000.cioar".into() }]
        );
        assert!(!reset.ended);
    }

    #[test]
    fn retraction_reaches_live_subscribers() {
        let d = RetentionDirectory::new(2);
        d.open_stream("s0");
        d.announce("s0-g0-00000.cioar", 0);
        let mut sub = d.subscribe();
        let _ = d.wait_for_prefix(&mut sub, "s0", Duration::from_millis(0)).unwrap();
        d.retract("s0-g0-00000.cioar");
        d.retract("s0-g0-00000.cioar"); // idempotent
        let batch = d.wait_for_prefix(&mut sub, "s0", Duration::from_millis(0)).unwrap();
        assert_eq!(
            batch.events,
            vec![StreamEvent::Retracted { archive: "s0-g0-00000.cioar".into() }]
        );
        // Retract-then-announce (a re-run) re-announces the name.
        d.announce("s0-g0-00000.cioar", 0);
        let again = d.wait_for_prefix(&mut sub, "s0", Duration::from_millis(0)).unwrap();
        assert_eq!(again.events.len(), 1);
        assert_eq!(d.feed_generation(), 4);
    }

    #[test]
    fn wait_spans_multiple_prefixes_and_wakes_on_announce() {
        let d = std::sync::Arc::new(RetentionDirectory::new(2));
        d.open_stream("s0");
        d.open_stream("s1");
        let bg = d.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            bg.announce("s1-g0-00000.cioar", 0);
            bg.end_stream("s0");
            bg.end_stream("s1");
        });
        let mut sub = d.subscribe();
        let batch =
            d.wait_for_prefixes(&mut sub, &["s0", "s1"], Duration::from_secs(10)).unwrap();
        assert_eq!(batch.events.len(), 1, "the announce must wake the blocked waiter");
        let fin = d.wait_for_prefixes(&mut sub, &["s0", "s1"], Duration::from_secs(10)).unwrap();
        assert!(fin.ended, "ended only once every requested stream ends");
        t.join().unwrap();
    }

    #[test]
    fn serve_accounting_spreads_over_archives_and_groups() {
        let d = RetentionDirectory::new(3);
        d.record_serve("x.cioar", 0);
        d.record_serve("x.cioar", 1);
        d.record_serve("y.cioar", 0);
        assert_eq!(d.archive_fills("x.cioar"), 2);
        assert_eq!(d.archive_fills("y.cioar"), 1);
        assert_eq!(d.serves("x.cioar", 0), 1);
        assert_eq!(d.group_serves(0), 2);
        assert_eq!(d.group_serves(2), 0);
    }
}
