//! TOML-subset parser for the `configs/` files (no `serde`/`toml` crates
//! offline).
//!
//! Supported: `[table]` and `[table.sub]` headers, `key = value` with
//! string / integer / float / boolean / homogeneous-array values, `#`
//! comments, and bare or quoted keys. Unsupported (and rejected loudly):
//! inline tables, arrays of tables, multi-line strings, datetimes — the
//! config schema in [`crate::config`] needs none of them.

use std::collections::BTreeMap;
use std::fmt;

/// A TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Quoted string.
    Str(String),
    /// Integer (i64).
    Int(i64),
    /// Float (f64).
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// Homogeneous array.
    Array(Vec<Value>),
}

impl Value {
    /// As string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    /// As integer (floats with zero fraction are not coerced).
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// As float (integers coerce).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// As array slice.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse error with line context.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
#[error("toml parse error at line {line}: {msg}")]
pub struct ParseError {
    /// 1-based line number.
    pub line: usize,
    /// Description.
    pub msg: String,
}

/// A parsed document: dotted-path key → value.
///
/// Keys are flattened: `[net]` + `torus_mbps = 425` becomes
/// `"net.torus_mbps"`. This keeps lookup trivial for the typed config
/// layer.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Document {
    map: BTreeMap<String, Value>,
}

impl Document {
    /// Parse a document from text.
    pub fn parse(text: &str) -> Result<Document, ParseError> {
        let mut doc = Document::default();
        let mut prefix = String::new();
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(body) = line.strip_prefix('[') {
                if line.starts_with("[[") {
                    return Err(err(lineno, "arrays of tables are not supported"));
                }
                let body = body
                    .strip_suffix(']')
                    .ok_or_else(|| err(lineno, "unterminated table header"))?
                    .trim();
                if body.is_empty() {
                    return Err(err(lineno, "empty table header"));
                }
                prefix = body.to_string();
            } else if let Some(eq) = find_top_level_eq(line) {
                let key = line[..eq].trim();
                let valtext = line[eq + 1..].trim();
                if key.is_empty() {
                    return Err(err(lineno, "empty key"));
                }
                let key = unquote_key(key);
                let value = parse_value(valtext, lineno)?;
                let full = if prefix.is_empty() { key } else { format!("{prefix}.{key}") };
                if doc.map.insert(full.clone(), value).is_some() {
                    return Err(err(lineno, &format!("duplicate key {full:?}")));
                }
            } else {
                return Err(err(lineno, &format!("expected `key = value`, got {line:?}")));
            }
        }
        Ok(doc)
    }

    /// Load from a file path.
    pub fn load(path: &std::path::Path) -> anyhow::Result<Document> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Document::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?)
    }

    /// Raw lookup by dotted path.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    /// Typed lookups (None if missing; Err-free by design — the config
    /// layer validates types with context).
    pub fn str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Value::as_str)
    }
    /// Integer lookup.
    pub fn int(&self, key: &str) -> Option<i64> {
        self.get(key).and_then(Value::as_int)
    }
    /// Float lookup (coerces ints).
    pub fn float(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Value::as_float)
    }
    /// Bool lookup.
    pub fn bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(Value::as_bool)
    }
    /// Array lookup.
    pub fn array(&self, key: &str) -> Option<&[Value]> {
        self.get(key).and_then(Value::as_array)
    }

    /// All keys under a dotted prefix (for table iteration).
    pub fn keys_under<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a str> + 'a {
        let want = format!("{prefix}.");
        self.map.keys().filter_map(move |k| k.strip_prefix(&want))
    }

    /// Number of keys.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when no keys were parsed.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

impl fmt::Display for Document {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (k, v) in &self.map {
            writeln!(f, "{k} = {v:?}")?;
        }
        Ok(())
    }
}

fn err(line: usize, msg: &str) -> ParseError {
    ParseError { line, msg: msg.to_string() }
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Find the `=` separating key from value, respecting quoted keys.
fn find_top_level_eq(line: &str) -> Option<usize> {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '=' if !in_str => return Some(i),
            _ => {}
        }
    }
    None
}

fn unquote_key(key: &str) -> String {
    key.trim_matches('"').to_string()
}

fn parse_value(text: &str, line: usize) -> Result<Value, ParseError> {
    let t = text.trim();
    if t.is_empty() {
        return Err(err(line, "missing value"));
    }
    if let Some(body) = t.strip_prefix('"') {
        let body = body.strip_suffix('"').ok_or_else(|| err(line, "unterminated string"))?;
        return Ok(Value::Str(unescape(body, line)?));
    }
    if t.starts_with('[') {
        let inner = t
            .strip_prefix('[')
            .and_then(|s| s.strip_suffix(']'))
            .ok_or_else(|| err(line, "unterminated array"))?;
        let mut items = Vec::new();
        for part in split_array(inner) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part, line)?);
            }
        }
        return Ok(Value::Array(items));
    }
    if t.starts_with('{') {
        return Err(err(line, "inline tables are not supported"));
    }
    match t {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    let clean = t.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(line, &format!("cannot parse value {t:?}")))
}

fn unescape(s: &str, line: usize) -> Result<String, ParseError> {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('\\') => out.push('\\'),
                Some('"') => out.push('"'),
                other => return Err(err(line, &format!("bad escape \\{other:?}"))),
            }
        } else {
            out.push(c);
        }
    }
    Ok(out)
}

/// Split a (single-line) array body on commas outside quotes/brackets.
fn split_array(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_typical_config() {
        let doc = Document::parse(
            r#"
            # BG/P Intrepid
            name = "bgp"
            [net]
            torus_mbps = 425
            tree_mbps = 850.0
            use_torus = true
            [gfs]
            servers = 24
            rates = [1, 2, 3]
            "#,
        )
        .unwrap();
        assert_eq!(doc.str("name"), Some("bgp"));
        assert_eq!(doc.int("net.torus_mbps"), Some(425));
        assert_eq!(doc.float("net.tree_mbps"), Some(850.0));
        assert_eq!(doc.float("net.torus_mbps"), Some(425.0), "int coerces to float");
        assert_eq!(doc.bool("net.use_torus"), Some(true));
        assert_eq!(doc.int("gfs.servers"), Some(24));
        let arr = doc.array("gfs.rates").unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].as_int(), Some(3));
    }

    #[test]
    fn comments_and_strings_with_hash() {
        let doc = Document::parse("key = \"a#b\" # trailing\n").unwrap();
        assert_eq!(doc.str("key"), Some("a#b"));
    }

    #[test]
    fn escapes() {
        let doc = Document::parse(r#"k = "a\nb\t\"q\"""#).unwrap();
        assert_eq!(doc.str("k"), Some("a\nb\t\"q\""));
    }

    #[test]
    fn underscored_numbers() {
        let doc = Document::parse("n = 163_840").unwrap();
        assert_eq!(doc.int("n"), Some(163_840));
    }

    #[test]
    fn nested_tables_flatten() {
        let doc = Document::parse("[a.b]\nc = 1\n").unwrap();
        assert_eq!(doc.int("a.b.c"), Some(1));
        assert_eq!(doc.keys_under("a.b").collect::<Vec<_>>(), vec!["c"]);
    }

    #[test]
    fn duplicate_key_rejected() {
        let e = Document::parse("a = 1\na = 2\n").unwrap_err();
        assert!(e.msg.contains("duplicate"));
        assert_eq!(e.line, 2);
    }

    #[test]
    fn unsupported_constructs_rejected() {
        assert!(Document::parse("[[t]]\n").is_err());
        assert!(Document::parse("a = {x = 1}\n").is_err());
        assert!(Document::parse("a = 1992-01-01\n").is_err());
    }

    #[test]
    fn malformed_lines_rejected() {
        assert!(Document::parse("just words\n").is_err());
        assert!(Document::parse("a = \"unterminated\n").is_err());
        assert!(Document::parse("[unclosed\n").is_err());
        assert!(Document::parse("a =\n").is_err());
    }

    #[test]
    fn nested_arrays() {
        let doc = Document::parse("a = [[1, 2], [3]]").unwrap();
        let outer = doc.array("a").unwrap();
        assert_eq!(outer.len(), 2);
        assert_eq!(outer[0].as_array().unwrap()[1].as_int(), Some(2));
    }

    #[test]
    fn empty_doc() {
        let doc = Document::parse("\n# only a comment\n").unwrap();
        assert!(doc.is_empty());
    }
}
