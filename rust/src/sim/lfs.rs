//! Local file system (LFS) model: the per-compute-node RAM disk.
//!
//! On the BG/P under ZeptoOS the LFS is a RAM-based file system with about
//! 1 GB free (2 GB on the striping-experiment nodes). The model tracks
//! capacity — the property every placement decision in §5.1 hinges on —
//! and exposes reserve/release with explicit failure on overflow, which the
//! collector uses for its `minFreeSpace` policy input.

use crate::util::units::fmt_bytes;

/// Errors from LFS capacity operations.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum LfsError {
    /// Not enough free space for a reservation.
    #[error("LFS full: requested {requested}, free {free} of {capacity}")]
    Full {
        /// Bytes requested.
        requested: u64,
        /// Bytes free at the time of the request.
        free: u64,
        /// Total capacity.
        capacity: u64,
    },
}

/// A RAM-disk with capacity accounting.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Lfs {
    capacity: u64,
    used: u64,
    /// High-water mark (diagnostics / DESIGN.md sizing).
    peak: u64,
}

impl Lfs {
    /// New LFS with the given capacity in bytes.
    pub fn new(capacity: u64) -> Self {
        Lfs { capacity, used: 0, peak: 0 }
    }

    /// Total capacity.
    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Bytes in use.
    pub fn used(&self) -> u64 {
        self.used
    }

    /// Bytes free.
    pub fn free(&self) -> u64 {
        self.capacity - self.used
    }

    /// High-water mark of usage.
    pub fn peak(&self) -> u64 {
        self.peak
    }

    /// Reserve `bytes`; fails without partial effect when it doesn't fit.
    pub fn reserve(&mut self, bytes: u64) -> Result<(), LfsError> {
        if bytes > self.free() {
            return Err(LfsError::Full { requested: bytes, free: self.free(), capacity: self.capacity });
        }
        self.used += bytes;
        self.peak = self.peak.max(self.used);
        Ok(())
    }

    /// Release a previous reservation (panics on under-release — that is
    /// always an accounting bug, not an environmental condition).
    pub fn release(&mut self, bytes: u64) {
        assert!(
            bytes <= self.used,
            "LFS release of {} exceeds used {}",
            fmt_bytes(bytes),
            fmt_bytes(self.used)
        );
        self.used -= bytes;
    }

    /// Would a reservation of `bytes` succeed?
    pub fn fits(&self, bytes: u64) -> bool {
        bytes <= self.free()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::{gib, mib};

    #[test]
    fn reserve_release_roundtrip() {
        let mut lfs = Lfs::new(gib(1));
        lfs.reserve(mib(100)).unwrap();
        assert_eq!(lfs.used(), mib(100));
        assert_eq!(lfs.free(), gib(1) - mib(100));
        lfs.release(mib(100));
        assert_eq!(lfs.used(), 0);
        assert_eq!(lfs.peak(), mib(100));
    }

    #[test]
    fn overflow_fails_without_effect() {
        let mut lfs = Lfs::new(mib(10));
        lfs.reserve(mib(8)).unwrap();
        let err = lfs.reserve(mib(4)).unwrap_err();
        assert_eq!(
            err,
            LfsError::Full { requested: mib(4), free: mib(2), capacity: mib(10) }
        );
        assert_eq!(lfs.used(), mib(8), "failed reserve must not change state");
    }

    #[test]
    fn exact_fit_succeeds() {
        let mut lfs = Lfs::new(mib(10));
        assert!(lfs.fits(mib(10)));
        lfs.reserve(mib(10)).unwrap();
        assert_eq!(lfs.free(), 0);
        assert!(!lfs.fits(1));
    }

    #[test]
    #[should_panic(expected = "exceeds used")]
    fn over_release_panics() {
        let mut lfs = Lfs::new(mib(10));
        lfs.release(1);
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut lfs = Lfs::new(mib(100));
        lfs.reserve(mib(60)).unwrap();
        lfs.release(mib(50));
        lfs.reserve(mib(20)).unwrap();
        assert_eq!(lfs.peak(), mib(60));
    }
}
