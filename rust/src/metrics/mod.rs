//! Metric computation and report emission shared by the figure benches
//! and the CLI: the paper's efficiency definition, aggregate-throughput
//! accounting, and paper-vs-measured comparison rows.

use crate::util::table::{num, Table};
use crate::util::units::mib;

pub mod timeline;

/// The paper's efficiency metric: ratio of an ideal (no-IO) makespan to
/// the measured makespan, clamped to [0, 1].
pub fn efficiency(ideal_makespan_s: f64, measured_makespan_s: f64) -> f64 {
    assert!(ideal_makespan_s > 0.0 && measured_makespan_s > 0.0);
    (ideal_makespan_s / measured_makespan_s).clamp(0.0, 1.0)
}

/// Aggregate throughput in MB/s given total bytes and elapsed seconds.
pub fn throughput_mbps(total_bytes: u64, elapsed_s: f64) -> f64 {
    assert!(elapsed_s > 0.0);
    total_bytes as f64 / elapsed_s / mib(1) as f64
}

/// One paper-vs-measured comparison row for EXPERIMENTS.md.
#[derive(Debug, Clone)]
pub struct Comparison {
    /// Series / condition label ("CIO 32K procs, 1MB").
    pub label: String,
    /// The paper's reported value.
    pub paper: f64,
    /// Our measured value.
    pub measured: f64,
    /// Unit for display.
    pub unit: &'static str,
}

impl Comparison {
    /// measured / paper.
    pub fn ratio(&self) -> f64 {
        self.measured / self.paper
    }
}

/// Collects comparisons and renders the table every figure bench prints.
#[derive(Debug, Clone, Default)]
pub struct Report {
    rows: Vec<Comparison>,
    title: String,
}

impl Report {
    /// Report titled after the figure it reproduces.
    pub fn new(title: &str) -> Self {
        Report { rows: Vec::new(), title: title.to_string() }
    }

    /// Add one comparison row.
    pub fn push(&mut self, label: impl Into<String>, paper: f64, measured: f64, unit: &'static str) {
        self.rows.push(Comparison { label: label.into(), paper, measured, unit });
    }

    /// All rows.
    pub fn rows(&self) -> &[Comparison] {
        &self.rows
    }

    /// Do all rows fall within `tol` relative deviation of the paper's
    /// value? (Loose by design: we match *shape*, not testbed absolutes.)
    pub fn within(&self, tol: f64) -> bool {
        self.rows.iter().all(|r| (r.ratio() - 1.0).abs() <= tol)
    }

    /// Worst-offending row (largest |ratio - 1|), if any.
    pub fn worst(&self) -> Option<&Comparison> {
        self.rows.iter().max_by(|a, b| {
            (a.ratio() - 1.0)
                .abs()
                .partial_cmp(&(b.ratio() - 1.0).abs())
                .unwrap()
        })
    }

    /// Render the paper-vs-measured table.
    pub fn render(&self) -> String {
        let mut t = Table::new(vec!["condition", "paper", "measured", "ratio", "unit"])
            .title(self.title.clone());
        for r in &self.rows {
            t.row(vec![
                r.label.clone(),
                num(r.paper),
                num(r.measured),
                format!("{:.2}x", r.ratio()),
                r.unit.to_string(),
            ]);
        }
        t.render()
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let mut t = Table::new(vec!["condition", "paper", "measured", "ratio", "unit"]);
        for r in &self.rows {
            t.row(vec![
                r.label.clone(),
                format!("{}", r.paper),
                format!("{}", r.measured),
                format!("{}", r.ratio()),
                r.unit.to_string(),
            ]);
        }
        t.to_csv()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn efficiency_clamps() {
        assert_eq!(efficiency(4.0, 8.0), 0.5);
        assert_eq!(efficiency(8.0, 4.0), 1.0, "faster than ideal clamps to 1");
    }

    #[test]
    fn throughput_units() {
        assert!((throughput_mbps(mib(100), 1.0) - 100.0).abs() < 1e-9);
        assert!((throughput_mbps(mib(100), 4.0) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn report_roundtrip() {
        let mut r = Report::new("Fig 16");
        r.push("GPFS peak", 250.0, 240.0, "MB/s");
        r.push("CIO peak", 2100.0, 2300.0, "MB/s");
        assert!(r.within(0.15));
        assert!(!r.within(0.05));
        assert_eq!(r.worst().unwrap().label, "CIO peak");
        let text = r.render();
        assert!(text.contains("Fig 16"));
        assert!(text.contains("GPFS peak"));
        let csv = r.to_csv();
        assert!(csv.starts_with("condition,paper,measured"));
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    #[should_panic]
    fn zero_time_rejected() {
        throughput_mbps(1, 0.0);
    }
}
