//! Size, bandwidth and simulated-time units.
//!
//! The simulator works in integer nanoseconds and integer bytes; bandwidths
//! are f64 bytes/second. Helpers here keep unit conversions explicit (the
//! paper mixes MB/s, Gb/s and GB/s, which is exactly how unit bugs happen).

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// Bytes, with constructors for the paper's units.
pub const KIB: u64 = 1 << 10;
/// 2^20 bytes.
pub const MIB: u64 = 1 << 20;
/// 2^30 bytes.
pub const GIB: u64 = 1 << 30;

/// Construct a byte count from KiB.
pub const fn kib(n: u64) -> u64 {
    n * KIB
}
/// Construct a byte count from MiB.
pub const fn mib(n: u64) -> u64 {
    n * MIB
}
/// Construct a byte count from GiB.
pub const fn gib(n: u64) -> u64 {
    n * GIB
}

/// Bandwidth in bytes/second from MB/s (decimal-ish; the paper quotes
/// MB/s = 2^20 B/s for file systems, we follow MiB/s consistently).
pub const fn mbps(n: u64) -> f64 {
    (n * MIB) as f64
}

/// Bandwidth in bytes/second from GB/s.
pub const fn gbps(n: f64) -> f64 {
    n * GIB as f64
}

/// Simulated time: integer nanoseconds since simulation start.
///
/// A newtype (not `std::time::Duration`) because simulated instants are
/// ordered keys in the event queue and arithmetic must be explicit,
/// overflow-checked, and `Copy`.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

impl SimTime {
    /// t = 0, the simulation epoch.
    pub const ZERO: SimTime = SimTime(0);
    /// Sentinel for "never".
    pub const NEVER: SimTime = SimTime(u64::MAX);

    /// From whole seconds.
    pub fn from_secs(s: u64) -> Self {
        SimTime(s * 1_000_000_000)
    }

    /// From fractional seconds (rounds to the nearest nanosecond).
    pub fn from_secs_f64(s: f64) -> Self {
        assert!(s >= 0.0 && s.is_finite(), "negative or non-finite SimTime: {s}");
        SimTime((s * 1e9).round() as u64)
    }

    /// From milliseconds.
    pub fn from_millis(ms: u64) -> Self {
        SimTime(ms * 1_000_000)
    }

    /// From microseconds.
    pub fn from_micros(us: u64) -> Self {
        SimTime(us * 1_000)
    }

    /// As fractional seconds.
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }

    /// Saturating difference.
    pub fn saturating_sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(rhs.0))
    }

    /// Time to move `bytes` at `bw` bytes/sec (rounds up to ≥1 ns so a
    /// transfer never completes at the instant it starts).
    pub fn transfer(bytes: u64, bw: f64) -> SimTime {
        assert!(bw > 0.0, "transfer at non-positive bandwidth");
        SimTime(((bytes as f64 / bw) * 1e9).ceil().max(1.0) as u64)
    }
}

impl Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.checked_add(rhs.0).expect("SimTime overflow"))
    }
}

impl AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        *self = *self + rhs;
    }
}

impl Sub for SimTime {
    type Output = SimTime;
    fn sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.checked_sub(rhs.0).expect("SimTime underflow"))
    }
}

impl fmt::Debug for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}s", self.as_secs_f64())
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = self.as_secs_f64();
        if s < 1e-6 {
            write!(f, "{:.0}ns", self.0)
        } else if s < 1e-3 {
            write!(f, "{:.1}us", self.0 as f64 / 1e3)
        } else if s < 1.0 {
            write!(f, "{:.1}ms", self.0 as f64 / 1e6)
        } else {
            write!(f, "{s:.2}s")
        }
    }
}

/// Human-readable byte count ("1.5 MiB").
pub fn fmt_bytes(b: u64) -> String {
    if b >= GIB {
        format!("{:.1} GiB", b as f64 / GIB as f64)
    } else if b >= MIB {
        format!("{:.1} MiB", b as f64 / MIB as f64)
    } else if b >= KIB {
        format!("{:.1} KiB", b as f64 / KIB as f64)
    } else {
        format!("{b} B")
    }
}

/// Human-readable bandwidth ("831.0 MB/s").
pub fn fmt_bw(bytes_per_sec: f64) -> String {
    let m = bytes_per_sec / MIB as f64;
    if m >= 1024.0 {
        format!("{:.2} GB/s", m / 1024.0)
    } else if m >= 1.0 {
        format!("{m:.1} MB/s")
    } else {
        format!("{:.1} KB/s", bytes_per_sec / KIB as f64)
    }
}

/// Parse a size string like "100MB", "4KB", "2GiB", "512" (bytes).
pub fn parse_bytes(s: &str) -> Option<u64> {
    let s = s.trim();
    let split = s.find(|c: char| !c.is_ascii_digit() && c != '.')?;
    let (num, unit) = s.split_at(split);
    let num: f64 = num.parse().ok()?;
    let mult = match unit.trim().to_ascii_lowercase().as_str() {
        "b" => 1,
        "k" | "kb" | "kib" => KIB,
        "m" | "mb" | "mib" => MIB,
        "g" | "gb" | "gib" => GIB,
        "t" | "tb" | "tib" => GIB * 1024,
        _ => return None,
    };
    Some((num * mult as f64).round() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        assert_eq!(kib(4), 4096);
        assert_eq!(mib(1), 1 << 20);
        assert_eq!(gib(2), 2 << 30);
        assert_eq!(mbps(100), 100.0 * (1 << 20) as f64);
    }

    #[test]
    fn simtime_roundtrip() {
        let t = SimTime::from_secs_f64(1.5);
        assert_eq!(t.0, 1_500_000_000);
        assert!((t.as_secs_f64() - 1.5).abs() < 1e-12);
        assert_eq!(SimTime::from_millis(2500), SimTime::from_secs_f64(2.5));
    }

    #[test]
    fn simtime_arithmetic() {
        let a = SimTime::from_secs(2);
        let b = SimTime::from_secs(3);
        assert_eq!(a + b, SimTime::from_secs(5));
        assert_eq!(b - a, SimTime::from_secs(1));
        assert_eq!(a.saturating_sub(b), SimTime::ZERO);
    }

    #[test]
    #[should_panic(expected = "underflow")]
    fn simtime_underflow_panics() {
        let _ = SimTime::from_secs(1) - SimTime::from_secs(2);
    }

    #[test]
    fn transfer_time() {
        // 100 MiB at 100 MiB/s = 1 s.
        let t = SimTime::transfer(mib(100), mbps(100));
        assert_eq!(t, SimTime::from_secs(1));
        // Zero bytes still takes 1 ns (events must advance time).
        assert_eq!(SimTime::transfer(0, mbps(1)).0, 1);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(mib(100)), "100.0 MiB");
        assert_eq!(fmt_bw(mbps(831)), "831.0 MB/s");
        assert_eq!(fmt_bw(gbps(2.4)), "2.40 GB/s");
        assert_eq!(format!("{}", SimTime::from_secs_f64(0.25)), "250.0ms");
    }

    #[test]
    fn parse_sizes() {
        assert_eq!(parse_bytes("100MB"), Some(mib(100)));
        assert_eq!(parse_bytes("4kb"), Some(kib(4)));
        assert_eq!(parse_bytes("2GiB"), Some(gib(2)));
        assert_eq!(parse_bytes("1.5MB"), Some(mib(3) / 2));
        assert_eq!(parse_bytes("nonsense"), None);
    }
}
