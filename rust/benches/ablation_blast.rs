//! §7 future work: BLAST-style read-many workloads on striped IFSs.
//!
//! Sweeps the stripe degree and the scale to show (a) the query-phase
//! speedup from striping, and (b) the crossover where the one-time
//! broadcast cost is amortized and CIO overtakes direct GFS reads.
//!
//! Regenerate: `cargo bench --bench ablation_blast`

#[path = "common/mod.rs"]
mod common;

use cio::config::ClusterConfig;
use cio::util::table::{num, Table};
use cio::workload::blast::BlastWorkload;

fn main() {
    let args = common::args();
    let procs = if common::fast() { 1024 } else { 4096 };
    let cfg = ClusterConfig::bgp(procs);

    // --- Stripe-degree sweep at fixed scale.
    let mut t1 = Table::new(vec![
        "stripe",
        "distribute (s)",
        "query CIO (s)",
        "query GPFS (s)",
        "end-to-end speedup",
    ])
    .title(format!("BLAST: 8 GiB DB, 2% slice per query, {procs} procs, 8 waves"));
    let wl = BlastWorkload { tasks: procs as u64 * 8, ..Default::default() };
    for &k in &[1u32, 4, 16, 32] {
        let r = wl.run(&cfg, k);
        t1.row(vec![
            format!("{k}"),
            num(r.distribution_s),
            num(r.cio_s),
            num(r.gpfs_s),
            format!("{:.2}x", r.speedup()),
        ]);
    }
    print!("{}", t1.render());

    // --- Amortization: waves sweep at the best stripe degree.
    let mut t2 = Table::new(vec!["query waves", "CIO total (s)", "GPFS (s)", "speedup"])
        .title("broadcast amortization (stripe=16)");
    for &waves in &[1u64, 2, 4, 8, 16] {
        let wl = BlastWorkload { tasks: procs as u64 * waves, ..Default::default() };
        let r = wl.run(&cfg, 16);
        t2.row(vec![
            format!("{waves}"),
            num(r.distribution_s + r.cio_s),
            num(r.gpfs_s),
            format!("{:.2}x", r.speedup()),
        ]);
    }
    print!("{}", t2.render());
    common::maybe_write_csv(&args, &t2.to_csv());
    println!("Reading: striping multiplies IFS serving bandwidth past the fixed GFS\naggregate; the broadcast pays for itself once the DB is re-read a few times\n— exactly the workload class §7 predicts will benefit.");
}
