//! Performance micro-benchmarks for the L3 hot paths (the §Perf inputs in
//! EXPERIMENTS.md): event-engine throughput, fluid-flow churn, collector
//! policy evaluation, archive writer/reader throughput, and PJRT scoring
//! latency (skipped when `make artifacts` has not run).
//!
//! Regenerate: `cargo bench --bench perf_micro`

#[path = "common/mod.rs"]
mod common;

use cio::cio::archive::{Compression, Reader, Writer};
use cio::cio::collector::Policy;
use cio::config::ClusterConfig;
use cio::sim::cluster::{IoMode, SimCluster};
use cio::sim::engine::Engine;
use cio::sim::flow::{FlowNet, HasFlowNet};
use cio::util::bench::{black_box, Bencher};
use cio::util::units::{mib, SimTime};
use std::time::Instant;

struct W {
    net: FlowNet<W>,
}
impl HasFlowNet for W {
    fn flownet(&mut self) -> &mut FlowNet<W> {
        &mut self.net
    }
}

fn main() {
    let mut b = Bencher::new();

    // --- DES engine: schedule+fire throughput.
    b.iter("engine: schedule+fire 1k events", || {
        let mut eng: Engine<u64> = Engine::new();
        let mut world = 0u64;
        for i in 0..1000u64 {
            eng.schedule(SimTime(i), |_, w| *w += 1);
        }
        eng.run(&mut world);
        black_box(world);
    });

    // --- Fluid flow network: 512-flow churn on a shared link.
    b.iter("flownet: 512 symmetric flows", || {
        let mut w = W { net: FlowNet::new() };
        let mut eng: Engine<W> = Engine::new();
        let link = w.net.add_resource("l", mib(1000) as f64);
        for _ in 0..512 {
            FlowNet::start(&mut eng, &mut w, &[link], mib(1), |_, _| {});
        }
        eng.run(&mut w);
        black_box(w.net.flows_completed());
    });

    // --- Collector policy evaluation (the per-commit hot call).
    let policy = Policy {
        max_delay: SimTime::from_secs(30),
        max_data: mib(256),
        min_free_space: mib(128),
    };
    let mut i = 0u64;
    b.iter("collector: policy should_flush", || {
        i = i.wrapping_add(7);
        black_box(policy.should_flush(SimTime(i % 60_000_000_000), i % mib(300), mib(500)));
    });

    // --- Whole-sim end-to-end rate: Figure-14 point as a macro bench.
    let cfg = ClusterConfig::bgp(4096);
    let events = {
        let t0 = Instant::now();
        let mut c = SimCluster::new(&cfg);
        let r = c.run_mtc(8192, 4.0, mib(1), IoMode::Cio);
        let dt = t0.elapsed();
        println!(
            "sim macro: 8192-task CIO run on 4096 procs: {:.3}s wall, {} events, {:.2} Mev/s",
            dt.as_secs_f64(),
            c.engine.processed(),
            c.engine.processed() as f64 / dt.as_secs_f64() / 1e6
        );
        assert_eq!(r.tasks, 8192);
        c.engine.processed()
    };
    black_box(events);

    // --- Archive writer / reader throughput (real IO).
    let dir = std::env::temp_dir().join(format!("cio-perf-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let payload = vec![0xABu8; 64 * 1024];
    let mut seq = 0u32;
    b.iter("archive: write 64 x 64KiB members", || {
        seq += 1;
        let path = dir.join(format!("w{seq}.cioar"));
        let mut w = Writer::create(&path).unwrap();
        for i in 0..64 {
            w.add(&format!("m{i}"), &payload, Compression::None).unwrap();
        }
        w.finish().unwrap();
        std::fs::remove_file(&path).unwrap();
    });
    let path = dir.join("read.cioar");
    let mut w = Writer::create(&path).unwrap();
    for i in 0..256 {
        w.add(&format!("m{i}"), &payload, Compression::None).unwrap();
    }
    w.finish().unwrap();
    let reader = Reader::open(&path).unwrap();
    b.iter("archive: random extract 1 of 256", || {
        let x = reader.extract("m128").unwrap();
        black_box(x.len());
    });

    // --- PJRT scoring latency (needs artifacts).
    match cio::runtime::ScoreModel::load_default() {
        Ok(model) => {
            let m = &model.meta;
            let lig = vec![0.5f32; m.batch * m.atoms * 4];
            let grid = vec![0.25f32; m.atoms * m.features];
            let wts = vec![1.0f32; m.features];
            b.iter("pjrt: score_batch (64 poses)", || {
                let s = model.score_batch(&lig, &grid, &wts).unwrap();
                black_box(s[0]);
            });
        }
        Err(e) => println!("pjrt bench skipped: {e}"),
    }

    b.report();
}
