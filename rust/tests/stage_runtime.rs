//! Integration: the multi-stage real-bytes runtime (§5.3 retention) and
//! the staging-publish atomicity/resilience fixes under concurrency.
//!
//! * `commit_during_flush_stress`: writers hammer commits while tight
//!   policies force continuous flushing — every byte must land in exactly
//!   one archive, with no truncated member ever observed (the atomic
//!   temp+rename publish under test).
//! * `vanished_staged_files_do_not_kill_collector`: files disappearing
//!   from staging mid-run must be skipped, counted, and never wedge the
//!   group's collector thread.
//! * `multistage_chain_hits_ifs_retention`: a 3-stage chain on real bytes
//!   where stage 2 reads its input archives from IFS retention (hit rate
//!   > 0 via the cache stats) and every byte round-trips.

use cio::cio::archive::{Compression, Reader, Writer};
use cio::cio::collector::Policy;
use cio::cio::fault::RetryPolicy;
use cio::cio::local::{LocalCollector, LocalLayout};
use cio::cio::local_stage::{
    archive_group, task_output_name, CacheSnapshot, GroupCache, StageExec, StageInput,
    StageRunner, StageRunnerConfig,
};
use cio::cio::stage::{CacheOutcome, StageGraph};
use cio::util::units::{kib, mib, SimTime};
use cio::workload::blast::RecordFormat;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

fn workspace(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("cio-stage-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Collect every archive member in `gfs`, asserting global uniqueness.
fn archived_members(gfs: &std::path::Path) -> BTreeMap<String, Vec<u8>> {
    let mut out = BTreeMap::new();
    for entry in std::fs::read_dir(gfs).unwrap() {
        let p = entry.unwrap().path();
        if p.extension().is_some_and(|e| e == "cioar") {
            let r = Reader::open(&p).unwrap();
            for e in r.entries() {
                let data = r.extract(&e.name).unwrap();
                let prev = out.insert(e.name.clone(), data);
                assert!(prev.is_none(), "member {} archived twice", e.name);
            }
        }
    }
    out
}

#[test]
fn commit_during_flush_stress() {
    // 8 writer threads commit continuously into 4 groups while a
    // hair-trigger policy keeps every group's collector flushing. The
    // CRC-checked re-read proves no archive ever captured a truncated
    // or half-published member.
    let root = workspace("stress");
    let nodes = 8u32;
    let layout = LocalLayout::create(&root, nodes, 2).unwrap(); // 4 groups
    let policy = Policy {
        max_delay: SimTime::from_millis(5),
        max_data: 512, // almost every commit trips a flush
        min_free_space: 0,
    };
    let collector = LocalCollector::start(&layout, policy, Compression::None);
    let writers = 8u32;
    let per_writer = 40u32;
    let expected = Mutex::new(BTreeMap::new());
    std::thread::scope(|scope| {
        for w in 0..writers {
            let layout = &layout;
            let collector = &collector;
            let expected = &expected;
            scope.spawn(move || {
                for i in 0..per_writer {
                    let node = (w + i) % nodes;
                    let name = format!("w{w}-i{i:03}.out");
                    // Distinct, verifiable payload per member.
                    let payload: Vec<u8> =
                        (0..200 + (i as usize % 37)).map(|j| (w as u8) ^ (j as u8)).collect();
                    std::fs::write(layout.lfs(node).join(&name), &payload).unwrap();
                    collector.commit(layout, node, &name).unwrap();
                    expected.lock().unwrap().insert(name, payload);
                }
            });
        }
    });
    let stats = collector.finish().unwrap();
    assert_eq!(stats.files, (writers * per_writer) as u64);
    assert_eq!(stats.flush_errors, 0, "no phantom errors under clean concurrency");
    let seen = archived_members(&layout.gfs());
    assert_eq!(seen, expected.into_inner().unwrap(), "every member byte-exact, none lost");
    // Staging fully drained, no temp residue anywhere.
    for g in 0..layout.ifs_groups() {
        let leftovers: Vec<_> = std::fs::read_dir(layout.ifs_staging(g))
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().to_string())
            .collect();
        assert!(leftovers.is_empty(), "group {g} staging not drained: {leftovers:?}");
    }
}

#[test]
fn vanished_staged_files_do_not_kill_collector() {
    // Interleave commits with deletions of already-staged files: the
    // collector must keep flushing the survivors and finish cleanly.
    let root = workspace("vanish-it");
    let layout = LocalLayout::create(&root, 2, 2).unwrap();
    let policy = Policy {
        max_delay: SimTime::from_secs(3600),
        max_data: mib(100), // flushes only at shutdown
        min_free_space: 0,
    };
    let collector = LocalCollector::start(&layout, policy, Compression::None);
    for i in 0..10u32 {
        let name = format!("f{i}.out");
        std::fs::write(layout.lfs(0).join(&name), vec![i as u8; 100]).unwrap();
        // Free-function commit: no wakeup, so the files sit in staging
        // until we delete half of them.
        cio::cio::local::commit_output(&layout, 0, &name).unwrap();
    }
    for i in (0..10u32).step_by(2) {
        std::fs::remove_file(layout.ifs_staging(0).join(format!("f{i}.out"))).unwrap();
    }
    let stats = collector.finish().unwrap();
    assert_eq!(stats.files, 5, "odd-numbered survivors archived");
    let seen = archived_members(&layout.gfs());
    assert_eq!(seen.len(), 5);
    for i in (1..10u32).step_by(2) {
        assert_eq!(seen[&format!("f{i}.out")], vec![i as u8; 100]);
    }
}

#[test]
fn multistage_chain_hits_ifs_retention() {
    // The Figure 17 setup on real bytes: stage 1 produces, its archives
    // are retained on the IFS; stage 2 re-reads them archive-as-input and
    // must be served from retention (hit rate > 0), transforming every
    // byte verifiably.
    let root = workspace("chain");
    let nodes = 6u32;
    let layout = LocalLayout::create(&root, nodes, 3).unwrap(); // 2 groups
    let graph = StageGraph::chain(&["produce", "transform", "reduce"]);
    let config = StageRunnerConfig {
        policy: Policy {
            max_delay: SimTime::from_secs(3600),
            max_data: 8 * 1024,
            min_free_space: 0,
        },
        compression: Compression::Deflate,
        cache_capacity: mib(64),
        neighbor_limit: mib(64),
        fill_chunk_bytes: kib(64),
        threads: 4,
        retry: RetryPolicy::default(),
        faults: None,
        repair: None,
    };
    let mut runner = StageRunner::new(layout, graph, config);
    let tasks = 24u32;
    let produce =
        |t: u32, _in: &StageInput<'_>| -> anyhow::Result<Vec<u8>> { Ok(vec![t as u8; 2048]) };
    let transform = |t: u32, input: &StageInput<'_>| -> anyhow::Result<Vec<u8>> {
        let (bytes, _outcome) = input.read_member(&task_output_name(0, "produce", t))?;
        anyhow::ensure!(bytes.len() == 2048 && bytes.iter().all(|&b| b == t as u8));
        // Transform: xor with 0xFF, halve.
        Ok(bytes[..1024].iter().map(|&b| b ^ 0xFF).collect())
    };
    let reduce = |_t: u32, input: &StageInput<'_>| -> anyhow::Result<Vec<u8>> {
        let mut total = 0u64;
        let mut n = 0u64;
        for t in 0..tasks {
            let (bytes, _) = input.read_member(&task_output_name(1, "transform", t))?;
            anyhow::ensure!(bytes.iter().all(|&b| b == (t as u8) ^ 0xFF), "task {t} corrupt");
            total += bytes.iter().map(|&b| b as u64).sum::<u64>();
            n += bytes.len() as u64;
        }
        Ok(format!("{n} bytes, checksum {total}").into_bytes())
    };
    let report = runner
        .run(&[
            StageExec { tasks, run: &produce },
            StageExec { tasks, run: &transform },
            StageExec { tasks: 1, run: &reduce },
        ])
        .unwrap();

    // Dataflow ran all three stages; stage 1 retained archives; stage 2
    // hit the retention cache.
    assert_eq!(report.stages.len(), 3);
    assert_eq!(report.stages[0].collector.files, tasks as u64);
    assert!(report.stages[0].collector.retained > 0, "stage-1 archives retained on IFS");
    assert!(!report.stages[0].archives.is_empty());
    assert!(
        report.stages[1].ifs_hits > 0,
        "stage 2 must read from IFS retention: {:?}",
        report.stages[1]
    );
    assert!(report.hit_rate() > 0.0);
    // Cache counters observable per group too.
    let snaps: Vec<CacheSnapshot> = runner.caches().iter().map(|c| c.snapshot()).collect();
    let hits: u64 = snaps.iter().map(|s| s.hits).sum();
    assert!(hits >= report.stages[1].ifs_hits);
    // Retained files live in the IFS data dirs, inside the cache budget.
    for (g, snap) in snaps.iter().enumerate() {
        let on_disk: u64 = std::fs::read_dir(runner.layout().ifs_data(g as u32))
            .unwrap()
            .map(|e| e.unwrap().metadata().unwrap().len())
            .sum();
        assert!(on_disk >= snap.used, "group {g}: accounting beyond disk ({on_disk} vs {})", snap.used);
    }
    // Final result is readable from GFS.
    let final_archive = &report.stages[2].archives[0];
    let r = Reader::open(&runner.layout().gfs().join(final_archive)).unwrap();
    let result = r.extract(&task_output_name(2, "reduce", 0)).unwrap();
    let text = String::from_utf8(result).unwrap();
    let expected_n = tasks as u64 * 1024;
    let expected_sum: u64 = (0..tasks as u64).map(|t| ((t as u8) ^ 0xFF) as u64 * 1024).sum();
    assert_eq!(text, format!("{expected_n} bytes, checksum {expected_sum}"));
}

#[test]
fn cross_group_reads_served_by_neighbor_transfers() {
    // All-to-all stage-2 reads on a many-group layout: every cross-group
    // archive resolve must be filled group-to-group from the producing
    // sibling's retention — with ample retention the GFS round-trip count
    // stays at zero after stage 1 (the §5.3 + torus-neighbor claim).
    let root = workspace("neighbor");
    let nodes = 4u32;
    let layout = LocalLayout::create(&root, nodes, 1).unwrap(); // 4 groups
    let graph = StageGraph::chain(&["produce", "gather"]);
    let config = StageRunnerConfig {
        policy: Policy {
            max_delay: SimTime::from_secs(3600),
            max_data: 1024,
            min_free_space: 0,
        },
        compression: Compression::None,
        cache_capacity: mib(64),
        neighbor_limit: mib(64),
        fill_chunk_bytes: kib(64),
        threads: 4,
        retry: RetryPolicy::default(),
        faults: None,
        repair: None,
    };
    let mut runner = StageRunner::new(layout, graph, config);
    let tasks = 8u32;
    let produce =
        |t: u32, _in: &StageInput<'_>| -> anyhow::Result<Vec<u8>> { Ok(vec![t as u8; 2048]) };
    let gather = move |_t: u32, input: &StageInput<'_>| -> anyhow::Result<Vec<u8>> {
        for t in 0..tasks {
            let (bytes, _) = input.read_member(&task_output_name(0, "produce", t))?;
            anyhow::ensure!(bytes == vec![t as u8; 2048], "task {t} corrupt");
        }
        Ok(vec![1])
    };
    let report = runner
        .run(&[StageExec { tasks, run: &produce }, StageExec { tasks, run: &gather }])
        .unwrap();
    let s = &report.stages[1];
    assert!(
        s.neighbor_transfers > 0,
        "cross-group resolves must be neighbor-served: {s:?}"
    );
    assert_eq!(s.gfs_misses, 0, "no read should round-trip through GFS: {s:?}");
    assert!(s.ifs_hits > 0, "own-group and post-fill resolves must hit: {s:?}");
    // The workflow totals agree with the per-group counters.
    let snaps: Vec<CacheSnapshot> = runner.caches().iter().map(|c| c.snapshot()).collect();
    let neighbors: u64 = snaps.iter().map(|s| s.neighbor_transfers).sum();
    assert_eq!(neighbors, report.neighbor_transfers());
    assert!(report.hit_rate() > 0.0);
}

#[test]
fn routed_alltoall_spreads_load_off_producer() {
    // The PR-4 acceptance workload: four 1-node groups, stage 1 produces,
    // stage 2 reads every member from every group. With ample retention
    // (>= 3 groups end up holding every popular stage-1 archive) the
    // central store must drop out of the steady state entirely, and the
    // retention directory must route fills to non-producing replicas —
    // so every producer serves strictly fewer transfers than under the
    // PR-3 producer-only policy (where it served all of them).
    let root = workspace("routed-spread");
    let nodes = 4u32;
    let layout = LocalLayout::create(&root, nodes, 1).unwrap(); // 4 groups
    let graph = StageGraph::chain(&["produce", "gather"]);
    let config = StageRunnerConfig {
        policy: Policy {
            max_delay: SimTime::from_secs(3600),
            max_data: 1024,
            min_free_space: 0,
        },
        compression: Compression::None,
        cache_capacity: mib(64),
        neighbor_limit: mib(64),
        // Sequential tasks: each fill is published to the directory
        // before the next resolve routes, so the spread is deterministic.
        fill_chunk_bytes: kib(64),
        threads: 1,
        retry: RetryPolicy::default(),
        faults: None,
        repair: None,
    };
    let mut runner = StageRunner::new(layout, graph, config);
    let tasks = 8u32;
    let produce =
        |t: u32, _in: &StageInput<'_>| -> anyhow::Result<Vec<u8>> { Ok(vec![t as u8; 2048]) };
    let gather = move |_t: u32, input: &StageInput<'_>| -> anyhow::Result<Vec<u8>> {
        for t in 0..tasks {
            let (bytes, _) = input.read_member(&task_output_name(0, "produce", t))?;
            anyhow::ensure!(bytes == vec![t as u8; 2048], "task {t} corrupt");
        }
        Ok(vec![1])
    };
    let report = runner
        .run(&[StageExec { tasks, run: &produce }, StageExec { tasks, run: &gather }])
        .unwrap();
    let s = &report.stages[1];
    assert_eq!(s.gfs_misses, 0, "no read may round-trip through GFS: {s:?}");
    assert!(s.neighbor_transfers > 0, "{s:?}");
    assert!(
        s.routed_transfers > 0,
        "the directory must route some fills off the producers: {s:?}"
    );
    assert_eq!(s.producer_transfers + s.routed_transfers, s.neighbor_transfers, "{s:?}");
    assert!(
        s.producer_transfers < s.neighbor_transfers,
        "producers must serve strictly fewer transfers than the producer-only policy: {s:?}"
    );
    assert_eq!(report.routed_transfers(), s.routed_transfers);

    // Every stage-1 archive is popular: at least 3 groups retain it.
    let dir = runner.directory();
    for name in &report.stages[0].archives {
        let sources = dir.sources(name);
        assert!(sources.len() >= 3, "popular archive {name} retained by {sources:?} only");
    }
    // Per-archive serve counters agree with the stage totals, the summed
    // producer share is strictly below the producer-only policy, and at
    // least one archive was served by two distinct sources (spread).
    let mut producer_served = 0u64;
    let mut total_fills = 0u64;
    let mut spread_archives = 0u32;
    for name in &report.stages[0].archives {
        let producer = archive_group(name).unwrap();
        producer_served += dir.serves(name, producer);
        total_fills += dir.archive_fills(name);
        let serving = (0..nodes).filter(|&g| dir.serves(name, g) > 0).count();
        if serving >= 2 {
            spread_archives += 1;
        }
    }
    assert_eq!(total_fills, s.neighbor_transfers);
    assert!(
        producer_served < total_fills,
        "producers served {producer_served} of {total_fills} cross-group fills"
    );
    assert!(spread_archives >= 1, "no archive was served from two distinct sources");
}

#[test]
fn eviction_churn_keeps_reads_byte_exact_and_directory_consistent() {
    // N reader threads across 3 groups race a background evictor that
    // keeps churning every group's retention with filler retains. Every
    // read must return byte-exact data regardless of which tier serves
    // it; a stale directory entry may only ever cost a fallback (counted)
    // — never a wrong read, an error, or a wedged fill latch — and at
    // quiescence the directory must agree with both the cache accounting
    // and the files on disk.
    let root = workspace("churn");
    let layout = LocalLayout::create(&root, 3, 1).unwrap(); // 3 groups
    let gfs = layout.gfs();
    fn payload(i: usize) -> Vec<u8> {
        (0..20_000usize).map(|j| (i as u8) ^ (j as u8)).collect()
    }
    let popular: Vec<String> = (0..4usize)
        .map(|i| {
            let name = format!("s0-g0-{i:05}.cioar");
            let mut w = Writer::create(&gfs.join(&name)).unwrap();
            w.add("m", &payload(i), Compression::None).unwrap();
            w.finish().unwrap();
            name
        })
        .collect();
    let fillers: Vec<String> = (0..3u32)
        .map(|g| {
            let name = format!("s9-g{g}-00000.cioar");
            let mut w = Writer::create(&gfs.join(&name)).unwrap();
            w.add("f", &vec![0x55u8; 20_000], Compression::None).unwrap();
            w.finish().unwrap();
            name
        })
        .collect();
    let arch_size = std::fs::metadata(gfs.join(&popular[0])).unwrap().len();
    // Room for ~2 archives per group: every fill or retain evicts.
    let caches = GroupCache::per_group_with(&layout, 2 * arch_size + 64, 2 * arch_size + 64);
    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let evictor = {
            let caches = &caches;
            let gfs = &gfs;
            let fillers = &fillers;
            let stop = &stop;
            scope.spawn(move || {
                let mut round = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    let g = round % 3;
                    caches[g].retain(&gfs.join(&fillers[g]), &fillers[g]).unwrap();
                    round += 1;
                    std::thread::yield_now();
                }
            })
        };
        let readers: Vec<_> = (0..6u32)
            .map(|t| {
                let caches = &caches;
                let gfs = &gfs;
                let popular = &popular;
                scope.spawn(move || {
                    for i in 0..40u32 {
                        let g = ((t + i) % 3) as usize;
                        let idx = ((t + i) % 4) as usize;
                        let name = &popular[idx];
                        let (r, _outcome) =
                            caches[g].open_archive_via(gfs, name, caches).unwrap();
                        let got = r.extract("m").unwrap();
                        assert_eq!(got, payload(idx), "reader {t} iter {i}: wrong bytes");
                    }
                })
            })
            .collect();
        for h in readers {
            h.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        evictor.join().unwrap();
    });

    // Quiescent agreement: listed in the directory <=> accounted by the
    // cache, and accounted => a real file on disk. In particular a group
    // is never listed as a source for an archive it evicted.
    let dir = caches[0].directory();
    for cache in caches.iter() {
        for name in popular.iter().chain(fillers.iter()) {
            let listed = dir.sources(name).contains(&cache.group());
            assert_eq!(
                listed,
                cache.contains(name),
                "directory vs accounting for {name} in group {}",
                cache.group()
            );
            if listed {
                assert!(
                    layout.ifs_data(cache.group()).join(name).is_file(),
                    "listed retention of {name} in group {} has no file",
                    cache.group()
                );
            }
        }
    }
    // Every miss was resolved by exactly one data movement or by joining
    // one; stale entries cost fallbacks, never unaccounted fills.
    for cache in caches.iter() {
        let snap = cache.snapshot();
        assert!(
            snap.misses >= snap.neighbor_transfers + snap.gfs_copies,
            "fills exceed misses in group {}: {snap:?}",
            cache.group()
        );
    }
    // No fill latch is wedged: a fresh resolve of every popular archive
    // still succeeds in every group, byte-exact.
    for cache in caches.iter() {
        for (i, name) in popular.iter().enumerate() {
            let (r, _) = cache.open_archive_via(&gfs, name, &caches).unwrap();
            assert_eq!(r.extract("m").unwrap(), payload(i), "post-churn read of {name}");
        }
    }
}

#[test]
fn record_granular_reads_cut_read_volume() {
    // Stage 2 reads records, not whole members: byte-exact slices at
    // record offsets, a contiguous span in one extent, and the honest
    // out-of-range error — all through the retention resolve.
    let root = workspace("records");
    let layout = LocalLayout::create(&root, 2, 2).unwrap();
    let graph = StageGraph::chain(&["produce", "scan"]);
    let config = StageRunnerConfig {
        policy: Policy {
            max_delay: SimTime::from_secs(3600),
            max_data: mib(1),
            min_free_space: 0,
        },
        compression: Compression::None, // records need uncompressed extents
        cache_capacity: mib(64),
        neighbor_limit: mib(64),
        fill_chunk_bytes: kib(64),
        threads: 2,
        retry: RetryPolicy::default(),
        faults: None,
        repair: None,
    };
    let mut runner = StageRunner::new(layout, graph, config);
    let fmt = RecordFormat { record_bytes: kib(4) as usize };
    let records_per_member = 8u64;
    let tasks = 4u32;
    let record_fill = |t: u32, r: u64| -> Vec<u8> {
        (0..fmt.record_bytes).map(|j| (t as u8) ^ (r as u8) ^ (j as u8)).collect()
    };
    let produce = move |t: u32, _in: &StageInput<'_>| -> anyhow::Result<Vec<u8>> {
        let mut out = Vec::new();
        for r in 0..records_per_member {
            out.extend(record_fill(t, r));
        }
        Ok(out)
    };
    let scan = move |t: u32, input: &StageInput<'_>| -> anyhow::Result<Vec<u8>> {
        let member = task_output_name(0, "produce", t);
        let mut read_volume = 0u64;
        // Single records, byte-exact, in scattered order.
        for r in [5u64, 0, 7, 3] {
            let (bytes, _) = fmt.read_record(input, &member, r)?;
            anyhow::ensure!(bytes == record_fill(t, r), "record {r} corrupt");
            read_volume += bytes.len() as u64;
        }
        // A contiguous span of 3 records in one extent.
        let (span, _) = fmt.read_records(input, &member, 2, 3)?;
        anyhow::ensure!(span.len() == 3 * fmt.record_bytes);
        for (k, r) in (2u64..5).enumerate() {
            let got = &span[k * fmt.record_bytes..(k + 1) * fmt.record_bytes];
            anyhow::ensure!(got == record_fill(t, r).as_slice(), "span record {r} corrupt");
        }
        read_volume += span.len() as u64;
        // Past-the-end records error instead of silently padding.
        anyhow::ensure!(fmt.read_record(input, &member, records_per_member).is_err());
        // The whole member would have been 8 records; we moved 7.
        Ok(read_volume.to_le_bytes().to_vec())
    };
    let report = runner
        .run(&[StageExec { tasks, run: &produce }, StageExec { tasks, run: &scan }])
        .unwrap();
    // Every scan task read 7 records' worth of bytes, not the member.
    let scan_archives = &report.stages[1].archives;
    assert!(!scan_archives.is_empty());
    let mut seen = 0u32;
    for name in scan_archives {
        let r = Reader::open(&runner.layout().gfs().join(name)).unwrap();
        for e in r.entries() {
            let volume = u64::from_le_bytes(r.extract(&e.name).unwrap().try_into().unwrap());
            assert_eq!(volume, 7 * fmt.record_bytes as u64);
            seen += 1;
        }
    }
    assert_eq!(seen, tasks);
}

#[test]
fn concurrent_disjoint_record_reads_share_one_cold_archive() {
    // The PR-5 acceptance shape: N readers hit N disjoint records of ONE
    // cold archive concurrently. Under the old whole-archive latch they
    // would serialize behind a single fill; under the chunked engine
    // each reader fetches its own covering chunks (plus the shared index
    // extent, fetched once) and no whole-archive fill ever happens —
    // asserted via the chunk-fill probe counters.
    let root = workspace("partial-conc");
    let layout = LocalLayout::create(&root, 1, 1).unwrap();
    let name = "s1-g0-00000.cioar";
    let record = 8192usize;
    let readers = 8usize;
    let data: Vec<u8> = (0..readers * record).map(|i| (i % 251) as u8).collect();
    {
        let mut w = Writer::create(&layout.gfs().join(name)).unwrap();
        w.add("m", &data, Compression::None).unwrap();
        w.finish().unwrap();
    }
    let total = std::fs::metadata(layout.gfs().join(name)).unwrap().len();
    let cache = GroupCache::new(&layout, 0, mib(64)).with_fill_chunk(record as u64);
    let barrier = std::sync::Barrier::new(readers);
    std::thread::scope(|scope| {
        for t in 0..readers {
            let cache = &cache;
            let layout = &layout;
            let barrier = &barrier;
            let data = &data;
            scope.spawn(move || {
                barrier.wait();
                let (bytes, _outcome) = cache
                    .read_member_range_via(
                        &layout.gfs(),
                        name,
                        &[],
                        "m",
                        (t * record) as u64,
                        record,
                    )
                    .unwrap();
                assert_eq!(
                    bytes,
                    data[t * record..(t + 1) * record],
                    "reader {t}: byte-exact disjoint record"
                );
            });
        }
    });
    let snap = cache.snapshot();
    assert_eq!(snap.gfs_copies, 0, "no whole-archive fill may happen: {snap:?}");
    assert!(snap.chunk_fills > 0, "{snap:?}");
    assert!(
        snap.chunk_fills <= total.div_ceil(record as u64),
        "chunk singleflight: no chunk moves twice even under contention: {snap:?}"
    );
    assert_eq!(snap.misses, readers as u64, "every cold record read is an honest miss");
    // The archive completed (the 8 records + index cover everything), so
    // it must have been promoted to ordinary retention.
    assert!(cache.contains(name), "completed partial promotes to retention: {snap:?}");
    assert_eq!(snap.partial_bytes, 0, "{snap:?}");
}

#[test]
fn partial_readers_race_evictor_byte_exact_no_wedged_latch() {
    // Churn: record readers resolve disjoint records of popular archives
    // through the partial engine while a background evictor keeps
    // churning retention (promotions race retains race eviction
    // unlinks). Every read must be byte-exact whatever tier serves it, a
    // lost race may only cost a counted fallback, and at quiescence no
    // chunk latch is wedged — a fresh read of every record still works.
    let root = workspace("partial-churn");
    let layout = LocalLayout::create(&root, 2, 1).unwrap(); // 2 groups
    let gfs = layout.gfs();
    let record = 4096usize;
    let records = 8usize;
    fn payload(i: usize, len: usize) -> Vec<u8> {
        (0..len).map(|j| ((i * 37) as u8) ^ (j as u8)).collect()
    }
    let popular: Vec<String> = (0..3usize)
        .map(|i| {
            let name = format!("s0-g0-{i:05}.cioar");
            let mut w = Writer::create(&gfs.join(&name)).unwrap();
            w.add("m", &payload(i, records * record), Compression::None).unwrap();
            w.finish().unwrap();
            name
        })
        .collect();
    let filler = "s9-g0-00000.cioar";
    {
        let mut w = Writer::create(&gfs.join(filler)).unwrap();
        w.add("f", &vec![0x5Au8; records * record], Compression::None).unwrap();
        w.finish().unwrap();
    }
    let arch = std::fs::metadata(gfs.join(&popular[0])).unwrap().len();
    // Fits ~2 archives per group: promotions and retains evict furiously.
    let caches = GroupCache::per_group_config(&layout, 2 * arch + 64, 2 * arch + 64, 4096);
    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let evictor = {
            let caches = &caches;
            let gfs = &gfs;
            let stop = &stop;
            scope.spawn(move || {
                let mut round = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    let g = round % 2;
                    caches[g].retain(&gfs.join(filler), filler).unwrap();
                    round += 1;
                    std::thread::yield_now();
                }
            })
        };
        let handles: Vec<_> = (0..6u32)
            .map(|t| {
                let caches = &caches;
                let gfs = &gfs;
                let popular = &popular;
                scope.spawn(move || {
                    for i in 0..60u32 {
                        let g = ((t + i) % 2) as usize;
                        let idx = ((t + i) % 3) as usize;
                        let r = ((t as usize + i as usize) * 5) % records;
                        let (bytes, _outcome) = caches[g]
                            .read_member_range_via(
                                gfs,
                                &popular[idx],
                                caches,
                                "m",
                                (r * record) as u64,
                                record,
                            )
                            .unwrap();
                        let want = payload(idx, records * record);
                        assert_eq!(
                            bytes,
                            want[r * record..(r + 1) * record],
                            "reader {t} iter {i}: wrong bytes"
                        );
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        evictor.join().unwrap();
    });
    // No wedged chunk latch: a fresh read of every record of every
    // archive still resolves, byte-exact, in every group.
    for cache in caches.iter() {
        for (i, name) in popular.iter().enumerate() {
            let want = payload(i, records * record);
            for r in 0..records {
                let (bytes, _) = cache
                    .read_member_range_via(
                        &gfs,
                        name,
                        &caches,
                        "m",
                        (r * record) as u64,
                        record,
                    )
                    .unwrap();
                assert_eq!(bytes, want[r * record..(r + 1) * record], "post-churn {name}:{r}");
            }
        }
    }
    // Quiescent agreement between directory, accounting, and disk still
    // holds with the partial engine in the mix.
    let dir = caches[0].directory();
    for cache in caches.iter() {
        for name in popular.iter().chain(std::iter::once(&filler.to_string())) {
            let listed = dir.sources(name).contains(&cache.group());
            assert_eq!(listed, cache.contains(name), "directory vs accounting for {name}");
            if listed {
                assert!(layout.ifs_data(cache.group()).join(name).is_file());
            }
        }
    }
}

#[test]
fn cold_runner_bootstraps_directory_from_foreign_manifests() {
    // ROADMAP follow-up: runner A ran an all-to-all with four 1-node
    // groups, so every group retained every stage-1 archive; runner B
    // comes up on the same root with only TWO groups, and its own
    // retention is wiped. B's caches can only warm-start groups 0 and 1
    // (both empty) — but StageRunner::new scans every
    // ifs/*/cache.manifest, so the directory also advertises groups 2
    // and 3's retention, and B's first fill routes group-to-group to a
    // bootstrapped source with zero GFS round trips — even to a
    // *non-producing* replica when the producer's copy is gone.
    let root = workspace("bootstrap");
    let layout_a = LocalLayout::create(&root, 4, 1).unwrap(); // 4 groups
    let config = StageRunnerConfig {
        policy: Policy {
            max_delay: SimTime::from_secs(3600),
            max_data: 1024,
            min_free_space: 0,
        },
        compression: Compression::None,
        cache_capacity: mib(64),
        neighbor_limit: mib(64),
        fill_chunk_bytes: kib(64),
        threads: 4,
        retry: RetryPolicy::default(),
        faults: None,
        repair: None,
    };
    let tasks = 8u32;
    let produce =
        |t: u32, _in: &StageInput<'_>| -> anyhow::Result<Vec<u8>> { Ok(vec![t as u8; 2048]) };
    let gather = move |_t: u32, input: &StageInput<'_>| -> anyhow::Result<Vec<u8>> {
        for t in 0..tasks {
            let (bytes, _) = input.read_member(&task_output_name(0, "produce", t))?;
            anyhow::ensure!(bytes == vec![t as u8; 2048], "task {t} corrupt");
        }
        Ok(vec![1])
    };
    let archives: Vec<String> = {
        let graph = StageGraph::chain(&["produce", "gather"]);
        let mut runner = StageRunner::new(layout_a.clone(), graph, config.clone());
        let report = runner
            .run(&[StageExec { tasks, run: &produce }, StageExec { tasks, run: &gather }])
            .unwrap();
        assert_eq!(report.stages[1].gfs_misses, 0);
        report.stages[0].archives.clone()
        // runner A drops -> every group's manifest persists
    };
    // Pick a victim produced by group 2; after the all-to-all every
    // group retains it. Kill the producer's copy and B's own groups'
    // retention entirely, and drop the canonical GFS copy — the only
    // live sources left are the foreign non-producing groups (3).
    let victim =
        archives.iter().find(|n| archive_group(n) == Some(2)).expect("group-2 archive").clone();
    std::fs::remove_file(layout_a.ifs_data(2).join(&victim)).unwrap();
    std::fs::remove_file(layout_a.gfs().join(&victim)).unwrap();
    for g in 0..2u32 {
        std::fs::remove_dir_all(layout_a.ifs_data(g)).unwrap();
        std::fs::create_dir_all(layout_a.ifs_data(g)).unwrap();
        let _ = std::fs::remove_file(layout_a.ifs_manifest(g));
    }

    let layout_b = LocalLayout { root: root.clone(), nodes: 2, cn_per_ifs: 1 }; // 2 groups
    let graph = StageGraph::chain(&["noop"]);
    let runner_b = StageRunner::new(layout_b, graph, config);
    let dir = runner_b.directory();
    assert!(
        dir.sources(&victim).contains(&3),
        "bootstrap must advertise group 3's retention of {victim}: {:?}",
        dir.sources(&victim)
    );
    assert!(
        !dir.sources(&victim).contains(&2),
        "the producer's dead copy must not be advertised: {:?}",
        dir.sources(&victim)
    );
    // Resolving the victim from B's group 0: a routed transfer from the
    // bootstrapped non-producing source — not GFS (no copy left), not
    // the producer (copy dead), not an error.
    let caches = runner_b.caches();
    let (reader, outcome) =
        caches[0].open_archive_via(&runner_b.layout().gfs(), &victim, caches).unwrap();
    assert_eq!(outcome, CacheOutcome::NeighborTransfer, "bootstrap-routed fill");
    assert!(!reader.is_empty());
    let snap = caches[0].snapshot();
    assert_eq!(
        (snap.neighbor_transfers, snap.routed_transfers, snap.gfs_copies, snap.gfs_direct),
        (1, 1, 0, 0),
        "routed to warm sibling retention with gfs_misses == 0: {snap:?}"
    );
    // Record reads resolve through bootstrapped sources too: B group 1
    // partial-reads a different high-group archive whose GFS copy is
    // also gone. (Find the group-3 archive actually holding task 3's
    // output — with per-commit flushes each g3 archive holds one task.)
    let member = task_output_name(0, "produce", 3); // node 3 -> group 3
    let other = archives
        .iter()
        .filter(|n| archive_group(n) == Some(3))
        .find(|n| {
            Reader::open(&layout_a.ifs_data(3).join(n.as_str()))
                .map(|r| r.entry(&member).is_some())
                .unwrap_or(false)
        })
        .expect("an archive holding task 3's output")
        .clone();
    std::fs::remove_file(layout_a.gfs().join(&other)).unwrap();
    let cold = caches.iter().find(|c| c.group() == 1).unwrap();
    let (bytes, outcome) = cold
        .read_member_range_via(&runner_b.layout().gfs(), &other, caches, &member, 0, 64)
        .unwrap();
    assert_eq!(outcome, CacheOutcome::NeighborTransfer);
    assert_eq!(bytes, vec![3u8; 64]);
    let snap = cold.snapshot();
    assert_eq!((snap.gfs_copies, snap.gfs_direct), (0, 0), "{snap:?}");
    assert!(snap.chunk_fills > 0, "chunks came from the bootstrapped source: {snap:?}");
}

#[test]
fn retention_warm_starts_across_runner_instances() {
    // §7 "learn from previous runs": a second StageRunner on the same
    // layout must warm-start its caches from the manifests the first one
    // persisted on drop — and serve hits from them without re-staging.
    let root = workspace("warmstart");
    let layout = LocalLayout::create(&root, 2, 2).unwrap();
    let config = StageRunnerConfig {
        policy: Policy {
            max_delay: SimTime::from_secs(3600),
            max_data: mib(1),
            min_free_space: 0,
        },
        compression: Compression::None,
        cache_capacity: mib(64),
        neighbor_limit: mib(64),
        fill_chunk_bytes: kib(64),
        threads: 2,
        retry: RetryPolicy::default(),
        faults: None,
        repair: None,
    };
    let produce =
        |t: u32, _in: &StageInput<'_>| -> anyhow::Result<Vec<u8>> { Ok(vec![t as u8; 512]) };
    let (archives, groups): (Vec<String>, u32) = {
        let graph = StageGraph::chain(&["produce"]);
        let mut runner = StageRunner::new(layout.clone(), graph, config.clone());
        let report = runner.run(&[StageExec { tasks: 6, run: &produce }]).unwrap();
        assert!(report.stages[0].collector.retained > 0);
        (report.stages[0].archives.clone(), runner.layout().ifs_groups())
        // runner drops here -> manifests persist
    };
    let graph = StageGraph::chain(&["produce"]);
    let warm = StageRunner::new(layout.clone(), graph, config);
    let mut warm_hits = 0;
    for name in &archives {
        let group = cio::cio::local_stage::archive_group(name).unwrap();
        assert!(group < groups);
        if warm.caches()[group as usize].contains(name) {
            let (r, outcome) =
                warm.caches()[group as usize].open_archive(&layout.gfs(), name).unwrap();
            assert_eq!(outcome, CacheOutcome::IfsHit);
            assert!(!r.is_empty());
            warm_hits += 1;
        }
    }
    assert!(
        warm_hits > 0,
        "at least one retained archive must survive into the next run: {archives:?}"
    );
}

#[test]
fn crash_restart_sweeps_residue_and_reconciles_manifest() {
    // PR 10: a runner killed mid-flush leaves `.tmp-*` publish residue,
    // `.partial-*` staging residue, and a torn manifest line behind. A
    // restart on the same tree must sweep the residue, reconcile the
    // manifest against the files actually on disk (counting the torn
    // line, trusting nothing), and serve every surviving retained
    // archive byte-exact.
    let root = workspace("crash-restart");
    let layout = LocalLayout::create(&root, 2, 2).unwrap();
    let config = StageRunnerConfig {
        policy: Policy {
            max_delay: SimTime::from_secs(3600),
            max_data: mib(1),
            min_free_space: 0,
        },
        compression: Compression::None,
        cache_capacity: mib(64),
        neighbor_limit: mib(64),
        fill_chunk_bytes: kib(64),
        threads: 2,
        retry: RetryPolicy::default(),
        faults: None,
        repair: None,
    };
    let produce =
        |t: u32, _in: &StageInput<'_>| -> anyhow::Result<Vec<u8>> { Ok(vec![t as u8; 512]) };
    let archives: Vec<String> = {
        let graph = StageGraph::chain(&["produce"]);
        let mut runner = StageRunner::new(layout.clone(), graph, config.clone());
        let report = runner.run(&[StageExec { tasks: 6, run: &produce }]).unwrap();
        assert!(report.stages[0].collector.retained > 0);
        report.stages[0].archives.clone()
        // runner drops here -> manifests persist (the "pre-crash" state)
    };
    // Simulate the crash's leftovers in group 0's data dir: an orphaned
    // publish temp (died between write and rename), a dead partial
    // staging file (its chunk bitmap died with the process), and a torn
    // trailing line on the manifest (a non-atomic torn disk write).
    let data0 = layout.ifs_data(0);
    std::fs::write(data0.join(".tmp-crashed-flush"), b"half-published garbage").unwrap();
    std::fs::write(data0.join(".partial-s0-gone.cioar"), vec![0u8; 4096]).unwrap();
    {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(layout.ifs_manifest(0))
            .unwrap();
        // Name present, bytes column torn mid-number into garbage.
        f.write_all(b"s0-torn-g0-99999.cioar\t12x4\n").unwrap();
    }

    let graph = StageGraph::chain(&["produce"]);
    let warm = StageRunner::new(layout.clone(), graph, config);
    // Residue swept on cache construction.
    let leftovers: Vec<String> = std::fs::read_dir(&data0)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().to_string())
        .filter(|n| n.starts_with(".tmp-") || n.starts_with(".partial-"))
        .collect();
    assert!(leftovers.is_empty(), "crash residue must be swept: {leftovers:?}");
    // The torn line was counted, not trusted — and the phantom archive it
    // named is neither accounted nor advertised.
    let g0 = &warm.caches()[0];
    assert_eq!(g0.manifest_corrupt_lines(), 1, "exactly the torn line counts");
    assert!(!g0.contains("s0-torn-g0-99999.cioar"));
    assert!(warm.directory().sources("s0-torn-g0-99999.cioar").is_empty());
    // Every archive the reconciled manifest still claims reads byte-exact
    // from retention.
    let mut warm_hits = 0;
    for name in &archives {
        let group = archive_group(name).unwrap() as usize;
        if warm.caches()[group].contains(name) {
            let (r, outcome) = warm.caches()[group].open_archive(&layout.gfs(), name).unwrap();
            assert_eq!(outcome, CacheOutcome::IfsHit);
            for e in r.entries() {
                let t: u32 = e.name.split('-').last().unwrap()
                    .strip_suffix(".out").unwrap().parse().unwrap();
                assert_eq!(r.extract(&e.name).unwrap(), vec![t as u8; 512], "{}", e.name);
            }
            warm_hits += 1;
        }
    }
    assert!(warm_hits > 0, "surviving retention must warm-start: {archives:?}");
}

#[test]
fn bounded_retention_evicts_to_capacity() {
    // A cache big enough for roughly one archive: retaining a stream of
    // archives must evict older ones (files unlinked) and never exceed
    // the budget.
    let root = workspace("bounded");
    let layout = LocalLayout::create(&root, 1, 1).unwrap();
    let gfs = layout.gfs();
    let mut sizes = Vec::new();
    for i in 0..4 {
        let name = format!("a{i}.cioar");
        let mut w = cio::cio::archive::Writer::create(&gfs.join(&name)).unwrap();
        w.add("payload", &vec![i as u8; 30_000], Compression::None).unwrap();
        w.finish().unwrap();
        sizes.push(std::fs::metadata(gfs.join(&name)).unwrap().len());
    }
    let cap = sizes[0] + sizes[1] / 2; // fits one, not two
    let cache = GroupCache::new(&layout, 0, cap);
    for i in 0..4 {
        assert!(cache.retain(&gfs.join(format!("a{i}.cioar")), &format!("a{i}.cioar")).unwrap());
        let snap = cache.snapshot();
        assert!(snap.used <= cap, "cache over budget: {} > {cap}", snap.used);
    }
    let snap = cache.snapshot();
    assert_eq!(snap.evictions, 3, "each retain evicted its predecessor");
    assert!(cache.contains("a3.cioar"));
    for i in 0..3 {
        assert!(
            !layout.ifs_data(0).join(format!("a{i}.cioar")).exists(),
            "evicted a{i} must be unlinked"
        );
    }
}
