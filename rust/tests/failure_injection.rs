//! Failure injection: degraded resources, overloaded staging, chirp OOM,
//! cancelled transfers, and dying retention sources must leave the system
//! consistent (every task accounted, no byte lost or double-counted, no
//! hangs).
//!
//! The second half is the PR-6 fault matrix: {error, delay past the
//! per-source deadline, torn transfer, ENOSPC} injected via the
//! [`FaultInjector`] into {neighbor chunk fetch, whole-archive fill, GFS
//! copy, collector retention}. Every cell must end in byte-exact reads
//! (or an honest decline for retention) with consistent counters —
//! never a wedge, never a wrong byte.
//!
//! PR 8 adds the silent-corruption column: `CorruptRange` flips one
//! in-flight byte of {neighbor fill, chunk fetch, GFS copy} without any
//! IO error. The checksum layer must catch every cell — the corrupt
//! landing is discarded and counted (`corruption_detected`), the fill
//! re-routes or retries, the reader observes only correct bytes, and a
//! repeat offender quarantines exactly like a failing source.

use cio::cio::archive::{Compression, Writer};
use cio::cio::fault::{is_retryable, is_timeout, FaultAction, FaultInjector, OpClass, RetryPolicy};
use cio::cio::local::LocalLayout;
use cio::cio::local_stage::GroupCache;
use cio::cio::stage::CacheOutcome;
use cio::config::ClusterConfig;
use cio::sim::cluster::{IoMode, SimCluster};
use cio::sim::flow::{FlowNet, HasFlowNet};
use cio::util::units::{kib, mbps, mib, SimTime};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

#[test]
fn gfs_brownout_mid_run_slows_but_completes() {
    // Drop the small-write aggregate to 10% for 20 simulated seconds,
    // then restore — a GPFS brownout.
    let cfg = ClusterConfig::bgp(1024);
    let healthy = {
        let mut c = SimCluster::new(&cfg);
        c.run_mtc(2048, 4.0, mib(1), IoMode::Gpfs)
    };
    let mut c = SimCluster::new(&cfg);
    c.engine.schedule(SimTime::from_secs(5), |e, w| {
        let id = w.res.gfs_small;
        FlowNet::set_capacity(e, w, id, mbps(25));
        e.schedule(SimTime::from_secs(20), move |e, w| {
            FlowNet::set_capacity(e, w, id, mbps(250));
        });
    });
    let degraded = c.run_mtc(2048, 4.0, mib(1), IoMode::Gpfs);
    assert_eq!(degraded.tasks, 2048);
    assert_eq!(degraded.gfs_bytes, 2048 * mib(1));
    assert!(
        degraded.makespan_tasks_s > healthy.makespan_tasks_s,
        "brownout must cost time: {} vs {}",
        degraded.makespan_tasks_s,
        healthy.makespan_tasks_s
    );
}

#[test]
fn tiny_staging_forces_spills_but_loses_nothing() {
    // Shrink the ION staging area so hard that the collector cannot keep
    // up — outputs must spill synchronously to GFS, not vanish.
    let mut cfg = ClusterConfig::bgp(512);
    cfg.node.server_mem = mib(8); // absurdly small staging
    cfg.collector.min_free_space = mib(2);
    cfg.collector.max_data = mib(4);
    let mut c = SimCluster::new(&cfg);
    let r = c.run_mtc(1024, 2.0, mib(1), IoMode::Cio);
    assert_eq!(r.tasks, 1024);
    assert!(r.staging_spills > 0, "staging this small must spill");
    assert_eq!(r.collector.files + r.staging_spills, 1024, "all outputs accounted");
    assert_eq!(r.gfs_bytes, 1024 * mib(1), "no bytes lost");
}

#[test]
fn chirp_oom_is_isolated_per_benchmark() {
    // An OOM on one benchmark run must not poison a following run on a
    // fresh cluster (state isolation).
    let cfg = ClusterConfig::bgp(2048).with_ifs_ratio(512);
    let mut c = SimCluster::new(&cfg);
    assert!(c.chirp_read_benchmark(512, mib(100)).is_err());
    let cfg2 = ClusterConfig::bgp(2048).with_ifs_ratio(64);
    let mut c2 = SimCluster::new(&cfg2);
    let agg = c2.chirp_read_benchmark(64, mib(100)).unwrap();
    assert!(agg > 0.0);
}

#[test]
fn cancelled_transfers_release_capacity() {
    // Cancel half the flows mid-flight; the survivors should finish
    // roughly twice as fast as if all had stayed.
    struct W {
        net: FlowNet<W>,
    }
    impl HasFlowNet for W {
        fn flownet(&mut self) -> &mut FlowNet<W> {
            &mut self.net
        }
    }
    let mut w = W { net: FlowNet::new() };
    let mut eng: cio::sim::Engine<W> = cio::sim::Engine::new();
    let link = w.net.add_resource("link", mbps(100));
    let mut victims = Vec::new();
    let last_done = std::rc::Rc::new(std::cell::RefCell::new(0.0f64));
    for i in 0..10 {
        let last_done = last_done.clone();
        let id = FlowNet::start(&mut eng, &mut w, &[link], mib(100), move |e, _| {
            *last_done.borrow_mut() = e.now().as_secs_f64();
        });
        if i % 2 == 0 {
            victims.push(id);
        }
    }
    eng.schedule(SimTime::from_millis(10), move |e, w| {
        for v in victims.clone() {
            assert!(FlowNet::cancel(e, w, v));
        }
    });
    eng.run(&mut w);
    // 10 flows of 100MiB on 100MiB/s = 10s each if all stayed (PS); with
    // half cancelled at t≈0, survivors share 5 ways -> ~5s. (Note: the
    // superseded wakeup event still advances the *engine* clock to 10s —
    // completion must be read from the callbacks.)
    let t = *last_done.borrow();
    assert!((4.5..6.0).contains(&t), "completion at {t}s");
    assert_eq!(w.net.flows_completed(), 5);
    assert_eq!(w.net.flows_cancelled(), 5);
}

#[test]
fn routed_source_unlinked_mid_resolve_falls_back_cleanly() {
    // The nearest retaining source's file is unlinked behind its
    // accounting's back (a crashed or wiped IFS server): a fill routed
    // there must fall back down the chain — next source -> producer ->
    // GFS — with consistent counters, and concurrent waiters sharing the
    // fill must see the final outcome, never the transient fault.
    let root = std::env::temp_dir()
        .join(format!("cio-fault-routed-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let layout = LocalLayout::create(&root, 4, 1).unwrap(); // 4 groups
    let name = "s0-g0-00000.cioar";
    let payload: Vec<u8> = (0..50_000usize).map(|j| (j % 251) as u8).collect();
    {
        let mut w = Writer::create(&layout.gfs().join(name)).unwrap();
        w.add("m", &payload, Compression::None).unwrap();
        w.finish().unwrap();
    }
    let caches = GroupCache::per_group_with(&layout, mib(16), mib(16));
    caches[0].retain(&layout.gfs().join(name), name).unwrap();
    // Group 3 pulls a replica: the directory now lists sources {0, 3}.
    let (_, outcome) = caches[3].open_archive_via(&layout.gfs(), name, &caches).unwrap();
    assert_eq!(outcome, CacheOutcome::NeighborTransfer);

    // Fault 1: group 3's retained file dies behind its accounting. A
    // group-1 reader is equidistant from 0 and 3; the serve-count
    // tie-break routes it to the idle group 3 first, where the dead file
    // must cost exactly one stale fallback to the NEXT source (the
    // producer) — not an error, and not a GFS round trip.
    std::fs::remove_file(layout.ifs_data(3).join(name)).unwrap();
    let (r, outcome) = caches[1].open_archive_via(&layout.gfs(), name, &caches).unwrap();
    assert_eq!(outcome, CacheOutcome::NeighborTransfer, "fallback stays on the neighbor tier");
    assert_eq!(r.extract("m").unwrap(), payload);
    let snap = caches[1].snapshot();
    assert_eq!(
        (snap.neighbor_transfers, snap.gfs_copies),
        (1, 0),
        "one fill, no GFS round trip: {snap:?}"
    );
    assert!(snap.stale_fallbacks >= 1, "the dead source must cost a fallback: {snap:?}");
    let dir = caches[1].directory();
    assert!(!dir.sources(name).contains(&3), "the dead entry must be withdrawn");
    assert!(dir.stale_withdrawals() >= 1);

    // Fault 2: every remaining retained copy dies too (groups 0 and 1).
    // Concurrent group-2 readers share one deduped fill that must fall
    // all the way to GFS; every waiter gets byte-exact data from the
    // shared final outcome rather than observing the mid-resolve faults.
    std::fs::remove_file(layout.ifs_data(0).join(name)).unwrap();
    std::fs::remove_file(layout.ifs_data(1).join(name)).unwrap();
    let threads = 6u32;
    let barrier = std::sync::Barrier::new(threads as usize);
    let served = AtomicU64::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let caches = &caches;
            let layout = &layout;
            let barrier = &barrier;
            let payload = &payload;
            let served = &served;
            scope.spawn(move || {
                barrier.wait();
                let (r, _outcome) =
                    caches[2].open_archive_via(&layout.gfs(), name, caches).unwrap();
                assert_eq!(&r.extract("m").unwrap(), payload, "byte-exact for every waiter");
                served.fetch_add(1, Ordering::Relaxed);
            });
        }
    });
    assert_eq!(served.load(Ordering::Relaxed), threads as u64);
    let snap = caches[2].snapshot();
    assert_eq!(snap.gfs_copies, 1, "exactly one deduped GFS fill: {snap:?}");
    assert_eq!(snap.neighbor_transfers, 0, "no live source was left: {snap:?}");
    assert!(snap.stale_fallbacks >= 2, "both dead sources probed and counted: {snap:?}");
    assert_eq!(snap.hits + snap.misses, threads as u64, "every reader accounted: {snap:?}");
    // The cluster healed: group 2 now holds the only live copy and is
    // the directory's sole source for the archive.
    assert_eq!(dir.sources(name), vec![2]);
}

#[test]
fn dispatcher_outage_window() {
    // Freeze dispatch for a window by brute force: run with a tiny rate
    // ceiling and verify the run still completes with heavy throttling.
    let mut cfg = ClusterConfig::bgp(256);
    cfg.dispatch.rate_ceiling = 50.0; // 50 tasks/s for 256 cores
    let mut c = SimCluster::new(&cfg);
    let r = c.run_mtc(512, 1.0, mib(1), IoMode::Cio);
    assert_eq!(r.tasks, 512);
    assert!(r.throttle_fraction > 0.9, "throttle {}", r.throttle_fraction);
    // 512 tasks at 50/s floor ≈ 10.2s minimum.
    assert!(r.makespan_tasks_s >= 10.0);
}

// ---------------------------------------------------------------------
// PR-6 fault matrix: injected faults through the read/fill chain.
// ---------------------------------------------------------------------

/// A fresh layout with `groups` IFS groups and one canonical archive on
/// GFS (produced by group 0), plus the payload it carries.
fn fault_fixture(tag: &str, groups: u32) -> (LocalLayout, String, Vec<u8>) {
    let root = std::env::temp_dir().join(format!("cio-pr6-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let layout = LocalLayout::create(&root, groups, 1).unwrap();
    let name = "s0-g0-00000.cioar".to_string();
    let payload: Vec<u8> = (0..60_000usize).map(|j| (j % 251) as u8).collect();
    let mut w = Writer::create(&layout.gfs().join(&name)).unwrap();
    w.add("m", &payload, Compression::None).unwrap();
    w.finish().unwrap();
    (layout, name, payload)
}

/// A retry policy with no sleeps and no deadline/quarantine side
/// effects — tests opt into each knob explicitly.
fn fast_retry() -> RetryPolicy {
    RetryPolicy {
        attempts: 3,
        backoff_base_ms: 0,
        backoff_cap_ms: 0,
        jitter_seed: 7,
        source_deadline_ms: 0,
        quarantine_streak: 0,
        probation_fills: 1,
        hedge_delay_ms: 0,
    }
}

#[test]
fn injected_neighbor_fault_reroutes_whole_archive_fill() {
    let (layout, name, payload) = fault_fixture("reroute", 4);
    let faults = Arc::new(FaultInjector::new());
    let caches = GroupCache::per_group_tuned(
        &layout,
        mib(16),
        mib(16),
        kib(64),
        fast_retry(),
        Some(faults.clone()),
    );
    caches[0].retain(&layout.gfs().join(&name), &name).unwrap();
    let (_, out) = caches[3].open_archive_via(&layout.gfs(), &name, &caches).unwrap();
    assert_eq!(out, CacheOutcome::NeighborTransfer);

    // A group-1 reader's first neighbor link faults on the wire; the
    // fill must re-route to the next retaining source — not GFS, not an
    // error, and no live retention withdrawn.
    faults.inject_times(OpClass::PublishLink, "/ifs/1/", FaultAction::Error, 1);
    let (r, out) = caches[1].open_archive_via(&layout.gfs(), &name, &caches).unwrap();
    assert_eq!(out, CacheOutcome::NeighborTransfer, "re-route stays on the neighbor tier");
    assert_eq!(&r.extract("m").unwrap(), &payload);
    let snap = caches[1].snapshot();
    assert_eq!(snap.rerouted_fills, 1, "one fill landed past a failed probe: {snap:?}");
    assert_eq!(snap.neighbor_transfers, 1, "{snap:?}");
    assert_eq!(snap.gfs_copies, 0, "{snap:?}");
    assert_eq!(snap.stale_fallbacks, 0, "a wire fault must not withdraw live retention: {snap:?}");
    assert_eq!(faults.injected(), 1);

    // Exhaust the whole neighbor tier for a group-2 reader: every link
    // faults, so the fill falls through to GFS — re-routed, byte-exact.
    faults.inject(OpClass::PublishLink, "/ifs/2/", FaultAction::Error);
    let (r, out) = caches[2].open_archive_via(&layout.gfs(), &name, &caches).unwrap();
    assert_eq!(out, CacheOutcome::GfsMiss, "exhausted neighbor tier falls through to GFS");
    assert_eq!(&r.extract("m").unwrap(), &payload);
    let snap = caches[2].snapshot();
    assert_eq!(snap.rerouted_fills, 1, "{snap:?}");
    assert_eq!(snap.gfs_copies, 1, "{snap:?}");
    assert_eq!(snap.neighbor_transfers, 0, "{snap:?}");
}

#[test]
fn torn_chunk_fetch_reroutes_record_read_byte_exact() {
    let (layout, name, payload) = fault_fixture("torn-chunk", 4);
    let faults = Arc::new(FaultInjector::new());
    let caches = GroupCache::per_group_tuned(
        &layout,
        mib(16),
        mib(16),
        kib(4),
        fast_retry(),
        Some(faults.clone()),
    );
    caches[0].retain(&layout.gfs().join(&name), &name).unwrap();

    // Every chunk read out of group 0's retention tears mid-transfer.
    // Record reads must detect the short read, charge the source, and
    // land the chunk runs from GFS — never mixing torn bytes in.
    faults.inject(OpClass::Read, "/ifs/0/data", FaultAction::TruncateAfter(128));
    let (bytes, _) = caches[1]
        .read_member_range_via(&layout.gfs(), &name, &caches, "m", 1000, 3000)
        .unwrap();
    assert_eq!(bytes, payload[1000..4000]);
    let snap = caches[1].snapshot();
    assert!(snap.rerouted_fills >= 1, "a torn source must re-route the run: {snap:?}");
    assert!(snap.chunk_fills >= 1, "{snap:?}");
    assert!(snap.partial_gfs_reads >= 1, "the bytes must have come from GFS: {snap:?}");
    assert_eq!(snap.stale_fallbacks, 0, "retention is intact, only the wire tore: {snap:?}");
    assert!(
        caches[1].directory().sources(&name).contains(&0),
        "the torn source keeps its live entry"
    );
}

#[test]
fn delay_past_deadline_aborts_the_probe_and_reroutes() {
    let (layout, name, payload) = fault_fixture("deadline", 4);
    let faults = Arc::new(FaultInjector::new());
    let mut policy = fast_retry();
    policy.source_deadline_ms = 20;
    let caches = GroupCache::per_group_tuned(
        &layout,
        mib(16),
        mib(16),
        kib(64),
        policy,
        Some(faults.clone()),
    );
    caches[0].retain(&layout.gfs().join(&name), &name).unwrap();
    let (_, out) = caches[3].open_archive_via(&layout.gfs(), &name, &caches).unwrap();
    assert_eq!(out, CacheOutcome::NeighborTransfer);

    // Every neighbor link to group 1 stalls past the per-source
    // deadline: both probes are discarded (slow data is never trusted
    // into the cache) and the fill re-routes to GFS.
    faults.inject(OpClass::PublishLink, "/ifs/1/", FaultAction::Delay(Duration::from_millis(60)));
    let (r, out) = caches[1].open_archive_via(&layout.gfs(), &name, &caches).unwrap();
    assert_eq!(out, CacheOutcome::GfsMiss);
    assert_eq!(&r.extract("m").unwrap(), &payload);
    let snap = caches[1].snapshot();
    assert_eq!(snap.deadline_aborts, 2, "both retaining sources blew the deadline: {snap:?}");
    assert_eq!(snap.rerouted_fills, 1, "{snap:?}");
    assert_eq!(snap.gfs_copies, 1, "{snap:?}");
    assert_eq!(snap.neighbor_transfers, 0, "{snap:?}");

    // The chunk engine enforces the same guard per run: slow source
    // reads are abandoned and the chunks land from GFS instead.
    faults.clear();
    faults.inject(OpClass::Read, "/ifs/", FaultAction::Delay(Duration::from_millis(60)));
    let (bytes, _) = caches[2]
        .read_member_range_via(&layout.gfs(), &name, &caches, "m", 500, 2000)
        .unwrap();
    assert_eq!(bytes, payload[500..2500]);
    let snap = caches[2].snapshot();
    assert!(snap.deadline_aborts >= 2, "every slow chunk probe must abort: {snap:?}");
    assert!(snap.rerouted_fills >= 1, "{snap:?}");
    assert!(snap.partial_gfs_reads >= 1, "{snap:?}");
}

#[test]
fn enospc_degrades_the_group_to_gfs_direct_and_a_probe_write_recovers_it() {
    let (layout, name, payload) = fault_fixture("enospc", 2);
    let faults = Arc::new(FaultInjector::new());
    let caches = GroupCache::per_group_tuned(
        &layout,
        mib(16),
        mib(16),
        kib(64),
        fast_retry(),
        Some(faults.clone()),
    );
    // Group 1's staging tree reports ENOSPC on every write-side op.
    faults.inject(OpClass::PublishCopy, "/ifs/1/", FaultAction::Enospc);
    faults.inject(OpClass::Write, "/ifs/1/", FaultAction::Enospc);

    // The fill cannot land, but the read must not fail: the group flips
    // to degraded GFS-direct serving, without burning retries on a
    // non-transient fault.
    let (r, out) = caches[1].open_archive_via(&layout.gfs(), &name, &caches).unwrap();
    assert_eq!(out, CacheOutcome::GfsMiss);
    assert_eq!(&r.extract("m").unwrap(), &payload);
    assert!(caches[1].is_degraded(), "ENOSPC must degrade, not error");
    let snap = caches[1].snapshot();
    assert_eq!(snap.degraded_reads, 1, "{snap:?}");
    assert_eq!(snap.retries, 0, "storage-full is terminal, never retried: {snap:?}");

    // While degraded: reads keep serving byte-exact from the canonical
    // copy, and retention is declined without error.
    let (r, out) = caches[1].open_archive_via(&layout.gfs(), &name, &caches).unwrap();
    assert_eq!(out, CacheOutcome::GfsMiss);
    assert_eq!(&r.extract("m").unwrap(), &payload);
    assert!(
        !caches[1].retain(&layout.gfs().join(&name), &name).unwrap(),
        "a degraded group declines retention instead of erroring"
    );
    assert!(caches[1].snapshot().degraded_reads >= 2);
    assert!(!caches[1].contains(&name), "nothing retained while degraded");

    // Space comes back: the next resolve's probe write clears the mode
    // and the fill lands for real; the read after that is a plain hit.
    faults.clear();
    let (r, out) = caches[1].open_archive_via(&layout.gfs(), &name, &caches).unwrap();
    assert_eq!(out, CacheOutcome::GfsMiss, "the recovery fill pays the GFS copy once");
    assert_eq!(&r.extract("m").unwrap(), &payload);
    assert!(!caches[1].is_degraded(), "a clean probe write must clear the mode");
    let (_, out) = caches[1].open_archive_via(&layout.gfs(), &name, &caches).unwrap();
    assert_eq!(out, CacheOutcome::IfsHit, "the recovered group retains again");
}

#[test]
fn retention_enospc_skips_the_collector_copy_without_losing_the_flush() {
    let (layout, name, _payload) = fault_fixture("retain-enospc", 2);
    let faults = Arc::new(FaultInjector::new());
    let caches = GroupCache::per_group_tuned(
        &layout,
        mib(16),
        mib(16),
        kib(64),
        fast_retry(),
        Some(faults.clone()),
    );
    faults.inject(OpClass::PublishCopy, "/ifs/0/", FaultAction::Enospc);
    faults.inject(OpClass::Write, "/ifs/0/", FaultAction::Enospc);

    // The collector's post-flush retention copy hits a full disk. The
    // flush already landed on GFS, so retention is skipped — degraded,
    // accounted, and silent — rather than erroring the collector.
    assert!(!caches[0].retain(&layout.gfs().join(&name), &name).unwrap());
    assert!(caches[0].is_degraded());
    assert!(layout.gfs().join(&name).is_file(), "the canonical copy is untouched");
    assert!(!caches[0].contains(&name), "accounting matches the disk: nothing landed");
    assert!(
        !caches[0].directory().sources(&name).contains(&0),
        "no phantom directory entry for the failed copy"
    );

    // Space returns: the probe write reopens retention.
    faults.clear();
    assert!(caches[0].retain(&layout.gfs().join(&name), &name).unwrap());
    assert!(!caches[0].is_degraded());
    assert!(caches[0].contains(&name));
    assert!(caches[0].directory().sources(&name).contains(&0));
}

#[test]
fn transient_gfs_fault_is_retried_and_waiters_see_only_the_final_outcome() {
    let (layout, name, payload) = fault_fixture("retry", 1);
    let faults = Arc::new(FaultInjector::new());
    let caches = GroupCache::per_group_tuned(
        &layout,
        mib(16),
        mib(16),
        kib(64),
        fast_retry(),
        Some(faults.clone()),
    );
    // The first GFS copy faults on the wire. The filler must retry the
    // whole chain (bounded, backed off) and land it, with every deduped
    // waiter observing only the final success — never the transient.
    faults.inject_times(OpClass::PublishCopy, ".cioar", FaultAction::Error, 1);
    let threads = 8u32;
    let barrier = std::sync::Barrier::new(threads as usize);
    let served = AtomicU64::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let caches = &caches;
            let layout = &layout;
            let name = &name;
            let barrier = &barrier;
            let payload = &payload;
            let served = &served;
            scope.spawn(move || {
                barrier.wait();
                let (r, _) = caches[0].open_archive_via(&layout.gfs(), name, caches).unwrap();
                assert_eq!(&r.extract("m").unwrap(), payload, "byte-exact for every waiter");
                served.fetch_add(1, Ordering::Relaxed);
            });
        }
    });
    assert_eq!(served.load(Ordering::Relaxed), u64::from(threads));
    let snap = caches[0].snapshot();
    assert_eq!(snap.retries, 1, "exactly one bounded retry: {snap:?}");
    assert_eq!(snap.gfs_copies, 1, "one deduped fill despite the fault: {snap:?}");
    assert_eq!(faults.injected(), 1);
    // The landed copy serves hits afterwards.
    let (_, out) = caches[0].open_archive_via(&layout.gfs(), &name, &caches).unwrap();
    assert_eq!(out, CacheOutcome::IfsHit);
}

#[test]
fn repeated_source_faults_trip_quarantine_and_probation_reopens_the_source() {
    let (layout, name, payload) = fault_fixture("quarantine", 4);
    let faults = Arc::new(FaultInjector::new());
    let mut policy = fast_retry();
    policy.quarantine_streak = 1; // one strike trips the breaker
    policy.probation_fills = 1; // one fill elsewhere reopens it
    let caches = GroupCache::per_group_tuned(
        &layout,
        mib(16),
        mib(16),
        kib(4),
        policy,
        Some(faults.clone()),
    );
    caches[0].retain(&layout.gfs().join(&name), &name).unwrap();

    // Group 0's wire faults on every chunk read: the reader's probes
    // charge its health, the breaker trips, and the read still lands
    // byte-exact from GFS.
    faults.inject(OpClass::Read, "/ifs/0/data", FaultAction::Error);
    let (bytes, _) = caches[1]
        .read_member_range_via(&layout.gfs(), &name, &caches, "m", 0, 2000)
        .unwrap();
    assert_eq!(bytes, payload[0..2000]);
    let dir = caches[1].directory();
    assert!(dir.is_quarantined(0), "a failing source must trip the breaker");
    assert!(dir.quarantine_trips() >= 1);
    let snap = caches[1].snapshot();
    assert!(snap.quarantined_sources >= 1, "the trip is charged to the reader: {snap:?}");
    assert!(snap.rerouted_fills >= 1, "{snap:?}");

    // The source heals. Fills landing elsewhere advance its probation
    // clock; the half-open probe then recovers it fully — reads keep
    // succeeding throughout (the chain is never stranded).
    faults.clear();
    let mut off = 8192usize;
    for _ in 0..4 {
        let (bytes, _) = caches[1]
            .read_member_range_via(&layout.gfs(), &name, &caches, "m", off as u64, 1000)
            .unwrap();
        assert_eq!(bytes, payload[off..off + 1000]);
        off += 8192;
        if !dir.is_quarantined(0) {
            break;
        }
    }
    assert!(!dir.is_quarantined(0), "probation must reopen a healthy source");
}

#[test]
fn stalled_gfs_copy_blows_the_deadline_and_recovers_on_retry() {
    let (layout, name, payload) = fault_fixture("gfs-deadline", 1);
    let faults = Arc::new(FaultInjector::new());
    let mut policy = fast_retry();
    policy.source_deadline_ms = 20;
    let caches = GroupCache::per_group_tuned(
        &layout,
        mib(16),
        mib(16),
        kib(64),
        policy,
        Some(faults.clone()),
    );

    // The central store hangs once, well past the per-source deadline.
    // The chunked GFS copy checks the clock in-loop and aborts
    // mid-transfer — a retryable timeout counted as a deadline abort —
    // and the bounded retry lands the fill on the healed store.
    faults.inject_times(
        OpClass::PublishCopy,
        ".cioar",
        FaultAction::Delay(Duration::from_millis(80)),
        1,
    );
    let (r, out) = caches[0].open_archive_via(&layout.gfs(), &name, &caches).unwrap();
    assert_eq!(out, CacheOutcome::GfsMiss);
    assert_eq!(&r.extract("m").unwrap(), &payload);
    let snap = caches[0].snapshot();
    assert_eq!(snap.deadline_aborts, 1, "the hung copy was abandoned at the deadline: {snap:?}");
    assert_eq!(snap.retries, 1, "one bounded retry re-landed it: {snap:?}");
    assert_eq!(snap.gfs_copies, 1, "{snap:?}");
    let leftovers: Vec<_> = std::fs::read_dir(layout.ifs_data(0))
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().to_string())
        .filter(|n| n.starts_with(".tmp-"))
        .collect();
    assert!(leftovers.is_empty(), "the aborted copy cleaned its temp file: {leftovers:?}");

    // A store that never recovers exhausts the bounded attempts and
    // surfaces a typed, retryable timeout — with the fill latch
    // released, so the next resolve starts fresh once the store heals.
    let name2 = "s0-g0-00001.cioar";
    let mut w = Writer::create(&layout.gfs().join(name2)).unwrap();
    w.add("m", &payload, Compression::None).unwrap();
    w.finish().unwrap();
    faults.inject(
        OpClass::PublishCopy,
        "00001.cioar",
        FaultAction::Delay(Duration::from_millis(80)),
    );
    let err = caches[0].open_archive_via(&layout.gfs(), name2, &caches).unwrap_err();
    assert!(is_timeout(&err), "the surfaced error is a typed timeout: {err:#}");
    assert!(is_retryable(&err), "{err:#}");
    let snap = caches[0].snapshot();
    assert_eq!(snap.deadline_aborts, 4, "all three attempts blew the deadline: {snap:?}");
    assert_eq!(snap.retries, 3, "{snap:?}");
    faults.clear();
    let (r2, out2) = caches[0].open_archive_via(&layout.gfs(), name2, &caches).unwrap();
    assert_eq!(out2, CacheOutcome::GfsMiss, "the healed store serves a fresh fill");
    assert_eq!(&r2.extract("m").unwrap(), &payload);
}

#[test]
fn quarantined_producer_is_probed_only_once_probation_opens() {
    let (layout, name, payload) = fault_fixture("producer-gate", 2);
    let name2 = "s0-g0-00001.cioar";
    let name3 = "s0-g0-00002.cioar";
    for n in [name2, name3] {
        let mut w = Writer::create(&layout.gfs().join(n)).unwrap();
        w.add("m", &payload, Compression::None).unwrap();
        w.finish().unwrap();
    }
    let faults = Arc::new(FaultInjector::new());
    let mut policy = fast_retry();
    policy.quarantine_streak = 1; // one strike trips the breaker
    policy.probation_fills = 8; // several fills elsewhere reopen it
    let caches = GroupCache::per_group_tuned(
        &layout,
        mib(16),
        mib(16),
        kib(4),
        policy,
        Some(faults.clone()),
    );
    for n in [name.as_str(), name2, name3] {
        caches[0].retain(&layout.gfs().join(n), n).unwrap();
    }

    // Strike one trips the breaker: the producer's outbound wire faults,
    // the read lands from GFS, and group 0 is quarantined.
    faults.inject(OpClass::PublishLink, "/ifs/1/", FaultAction::Error);
    let (r, out) = caches[1].open_archive_via(&layout.gfs(), &name, &caches).unwrap();
    assert_eq!(out, CacheOutcome::GfsMiss);
    assert_eq!(&r.extract("m").unwrap(), &payload);
    let dir = caches[1].directory();
    assert!(dir.is_quarantined(0));
    assert!(
        !dir.probe_allowed(0),
        "freshly tripped: not even the producer fallback may probe it"
    );

    // While the breaker is closed, reads of the producer's other
    // archives must go straight to GFS without probing it at all — no
    // routed candidate, no producer-fallback probe, even though the
    // source is healthy again (only the breaker gates it).
    faults.clear();
    let (bytes, _) = caches[1]
        .read_member_range_via(&layout.gfs(), name2, &caches, "m", 100, 2000)
        .unwrap();
    assert_eq!(bytes, payload[100..2100]);
    let snap = caches[1].snapshot();
    assert_eq!(
        snap.partial_neighbor_reads + snap.partial_routed_reads,
        0,
        "no chunk was pulled from the gated producer: {snap:?}"
    );
    assert!(snap.partial_gfs_reads >= 1, "{snap:?}");
    assert_eq!(snap.stale_fallbacks, 0, "gating is not staleness: {snap:?}");

    // Fills landing elsewhere advance the probation clock; once it
    // matures the breaker goes half-open and the producer is
    // probe-eligible again.
    for i in 0..12u32 {
        if dir.probe_allowed(0) {
            break;
        }
        // GFS-only filler archives produced by the reader's own group:
        // no routing involved, each fill just advances the clock.
        let filler = format!("s9-g1-{i:05}.cioar");
        let mut w = Writer::create(&layout.gfs().join(&filler)).unwrap();
        w.add("m", &payload[..1000], Compression::None).unwrap();
        w.finish().unwrap();
        let (_, out) = caches[1].open_archive_via(&layout.gfs(), &filler, &caches).unwrap();
        assert_eq!(out, CacheOutcome::GfsMiss);
    }
    assert!(dir.probe_allowed(0), "enough fills elsewhere must open the probation window");
    assert!(dir.is_quarantined(0), "half-open still counts as quarantined until a probe lands");

    // The next read's successful probe recovers the producer fully.
    let (r3, out3) = caches[1].open_archive_via(&layout.gfs(), name3, &caches).unwrap();
    assert_eq!(out3, CacheOutcome::NeighborTransfer, "the half-open probe lands");
    assert_eq!(&r3.extract("m").unwrap(), &payload);
    assert!(!dir.is_quarantined(0), "a successful probe closes the breaker");
}

// ---------------------------------------------------------------------
// PR-8 corruption matrix: one silently flipped byte per transfer tier.
// ---------------------------------------------------------------------

#[test]
fn corrupt_neighbor_fill_is_caught_and_rerouted_byte_exact() {
    let (layout, name, payload) = fault_fixture("corrupt-neighbor", 4);
    let faults = Arc::new(FaultInjector::new());
    let caches = GroupCache::per_group_tuned(
        &layout,
        mib(16),
        mib(16),
        kib(64),
        fast_retry(),
        Some(faults.clone()),
    );
    caches[0].retain(&layout.gfs().join(&name), &name).unwrap();
    let (_, out) = caches[3].open_archive_via(&layout.gfs(), &name, &caches).unwrap();
    assert_eq!(out, CacheOutcome::NeighborTransfer);

    // A group-1 reader's first neighbor transfer flips one payload byte
    // in flight — no IO error, just wrong bytes. The checksum gate must
    // discard the landing, charge the source, and re-route to the next
    // retaining source; the reader never sees the flip.
    faults.inject_times(OpClass::PublishLink, "/ifs/1/", FaultAction::CorruptRange(100), 1);
    let (r, out) = caches[1].open_archive_via(&layout.gfs(), &name, &caches).unwrap();
    assert_eq!(out, CacheOutcome::NeighborTransfer, "re-route stays on the neighbor tier");
    assert_eq!(&r.extract("m").unwrap(), &payload, "the flipped byte never reaches the reader");
    let snap = caches[1].snapshot();
    assert_eq!(snap.corruption_detected, 1, "{snap:?}");
    assert_eq!(snap.rerouted_fills, 1, "{snap:?}");
    assert_eq!((snap.neighbor_transfers, snap.gfs_copies), (1, 0), "{snap:?}");
    assert_eq!(
        snap.stale_fallbacks, 0,
        "corruption charges health, it does not withdraw live retention: {snap:?}"
    );
    // The landed (clean) copy verifies end to end.
    assert!(matches!(
        cio::cio::archive::verify_archive(&layout.ifs_data(1).join(&name)).unwrap(),
        cio::cio::archive::Verification::Verified
    ));
}

#[test]
fn corrupt_chunk_fetch_lands_the_record_from_gfs_byte_exact() {
    let (layout, name, payload) = fault_fixture("corrupt-chunk", 4);
    let faults = Arc::new(FaultInjector::new());
    let caches = GroupCache::per_group_tuned(
        &layout,
        mib(16),
        mib(16),
        kib(4),
        fast_retry(),
        Some(faults.clone()),
    );
    caches[0].retain(&layout.gfs().join(&name), &name).unwrap();

    // Every chunk read out of group 0's retention flips its first byte.
    // The per-span checksum check must reject the chunks and land the
    // run from GFS — never mixing a flipped byte into the staging file.
    faults.inject(OpClass::Read, "/ifs/0/data", FaultAction::CorruptRange(0));
    let (bytes, _) = caches[1]
        .read_member_range_via(&layout.gfs(), &name, &caches, "m", 1000, 3000)
        .unwrap();
    assert_eq!(bytes, payload[1000..4000], "flipped chunks never reach the reader");
    let snap = caches[1].snapshot();
    assert!(snap.corruption_detected >= 1, "{snap:?}");
    assert!(snap.rerouted_fills >= 1, "{snap:?}");
    assert!(snap.partial_gfs_reads >= 1, "the bytes must have come from GFS: {snap:?}");
    assert_eq!(snap.stale_fallbacks, 0, "retention is intact, only the wire flips: {snap:?}");
}

#[test]
fn corrupt_gfs_copy_is_retried_and_lands_verified() {
    let (layout, name, payload) = fault_fixture("corrupt-gfs", 1);
    let faults = Arc::new(FaultInjector::new());
    let caches = GroupCache::per_group_tuned(
        &layout,
        mib(16),
        mib(16),
        kib(64),
        fast_retry(),
        Some(faults.clone()),
    );
    // The first GFS copy flips one byte in the stream; the copy
    // "succeeds". Post-landing verification must catch it, discard the
    // file, and surface a retryable corrupt failure the bounded retry
    // chain re-fetches.
    faults.inject_times(OpClass::PublishCopy, ".cioar", FaultAction::CorruptRange(200), 1);
    let (r, out) = caches[0].open_archive_via(&layout.gfs(), &name, &caches).unwrap();
    assert_eq!(out, CacheOutcome::GfsMiss);
    assert_eq!(&r.extract("m").unwrap(), &payload);
    let snap = caches[0].snapshot();
    assert_eq!(snap.corruption_detected, 1, "{snap:?}");
    assert_eq!(snap.retries, 1, "one bounded retry re-landed it: {snap:?}");
    assert_eq!(snap.gfs_copies, 1, "only the clean landing is counted: {snap:?}");
    assert!(matches!(
        cio::cio::archive::verify_archive(&layout.ifs_data(0).join(&name)).unwrap(),
        cio::cio::archive::Verification::Verified
    ));
    // And it serves plain hits afterwards.
    let (_, out) = caches[0].open_archive_via(&layout.gfs(), &name, &caches).unwrap();
    assert_eq!(out, CacheOutcome::IfsHit);
}

#[test]
fn repeat_corrupting_source_trips_quarantine() {
    let (layout, name, payload) = fault_fixture("corrupt-repeat", 4);
    let faults = Arc::new(FaultInjector::new());
    let mut policy = fast_retry();
    policy.quarantine_streak = 2; // K strikes trip the breaker
    policy.probation_fills = 8;
    let caches = GroupCache::per_group_tuned(
        &layout,
        mib(16),
        mib(16),
        kib(4),
        policy,
        Some(faults.clone()),
    );
    caches[0].retain(&layout.gfs().join(&name), &name).unwrap();

    // Group 0 flips a byte on *every* chunk it serves — a bit-flipping
    // replica. Each corrupt span charges its health exactly like a
    // failing probe; after K mismatches the breaker trips and readers
    // stop routing to it, while every read stays byte-exact throughout.
    faults.inject(OpClass::Read, "/ifs/0/data", FaultAction::CorruptRange(0));
    let dir = caches[1].directory();
    let mut off = 0usize;
    for _ in 0..4 {
        let (bytes, _) = caches[1]
            .read_member_range_via(&layout.gfs(), &name, &caches, "m", off as u64, 2000)
            .unwrap();
        assert_eq!(bytes, payload[off..off + 2000], "byte-exact under a flipping source");
        if dir.is_quarantined(0) {
            break;
        }
        off += 16384;
    }
    assert!(dir.is_quarantined(0), "K corrupt serves must trip the breaker");
    let snap = caches[1].snapshot();
    assert!(snap.corruption_detected >= 2, "{snap:?}");
    assert!(snap.quarantined_sources >= 1, "{snap:?}");
}
