//! Figure 12: aggregate IFS read performance as the stripe degree grows
//! from 1 to 32 LFSs (MosaStore-style striping over the torus).
//!
//! Paper anchors: 158 MB/s at degree 1 → 831 MB/s at degree 32; the
//! 32 × 2 GB configuration also yields a 64 GB IFS (capacity check).
//!
//! Regenerate: `cargo bench --bench fig12`

#[path = "common/mod.rs"]
mod common;

use cio::config::ClusterConfig;
use cio::metrics::Report;
use cio::sim::cluster::SimCluster;
use cio::sim::ifs::StripeSet;
use cio::util::table::{num, Table};
use cio::util::units::{gib, mib};

fn main() {
    let args = common::args();
    let degrees: &[u32] = &[1, 2, 4, 8, 16, 32];
    let clients = 64u32;
    let size = mib(100);

    let mut table = Table::new(vec!["stripe degree", "aggregate MB/s", "IFS capacity"])
        .title("Figure 12: striped IFS read bandwidth (64 clients x 100 MB)");
    let mut report = Report::new("Figure 12 anchors");

    for &k in degrees {
        let cfg = ClusterConfig::bgp(1024).with_stripe(k);
        let mut cluster = SimCluster::new(&cfg);
        let agg = cluster.chirp_read_benchmark(clients, size).expect("no OOM at 64 clients")
            / mib(1) as f64;
        let capacity = StripeSet::new(k, cfg.ifs.member_capacity).capacity();
        table.row(vec![format!("{k}"), num(agg), cio::util::units::fmt_bytes(capacity)]);
        match k {
            1 => report.push("degree 1", 158.0, agg, "MB/s"),
            32 => report.push("degree 32", 831.0, agg, "MB/s"),
            _ => {}
        }
    }
    print!("{}", table.render());
    common::maybe_write_csv(&args, &table.to_csv());
    // Capacity anchor: 32 x 2 GB = 64 GB.
    let cap = StripeSet::new(32, gib(2)).capacity();
    println!("32-way stripe capacity: {} (paper: 64 GB)\n", cio::util::units::fmt_bytes(cap));
    common::footer(&report);
}
