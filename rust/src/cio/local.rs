//! Real-bytes local runtime: the same collective-IO machinery operating on
//! actual directories with threads.
//!
//! The simulator reproduces the paper's *scale* numbers; this module
//! proves the *mechanisms* on real data: a directory tree standing in for
//! the storage hierarchy (`gfs/`, `ifs/<group>/staging/`, `lfs/<node>/`),
//! a threaded output collector running the §5.2 policy loop over real
//! files and real [`crate::cio::archive`] archives, and a spanning-tree
//! distributor that materializes replicas by copying files in tree order.
//! Integration tests and the `dock_screening` example run on this.
//!
//! Concurrency shape (the PR-1 hot-path rework):
//!
//! * the collector is **condvar-driven**: [`LocalCollector::commit`]
//!   moves the file and wakes the owning group's collector thread, which
//!   does one batched `read_dir` scan and evaluates [`Policy`] — no
//!   sleep-poll loop, so flush latency tracks the commit, not a poll
//!   quantum. A coarse rescan backstop (and the `maxDelay` deadline)
//!   still picks up files committed by the notification-free
//!   [`commit_output`] free function.
//! * each IFS group's collector builds its archives independently, and
//!   within a flush the members are deflated by the
//!   [`crate::cio::archive`] parallel-compression pipeline;
//! * [`distribute_to_ifs`] executes the broadcast schedule **pipelined**:
//!   a replica that lands early immediately starts feeding its children
//!   instead of waiting for the slowest copy of its round (the old
//!   per-round barrier).

use crate::cio::archive::{Compression, Writer};
use crate::cio::collector::{CollectorStats, FlushReason, Policy};
use crate::cio::distributor::TreeShape;
use crate::util::units::SimTime;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// How often an idle collector rescans for files committed without a
/// wakeup (the [`commit_output`] free-function path). Notified commits
/// never wait on this.
const UNNOTIFIED_RESCAN: Duration = Duration::from_millis(250);

/// Directory layout for a local run.
#[derive(Debug, Clone)]
pub struct LocalLayout {
    /// Root of the hierarchy.
    pub root: PathBuf,
    /// Number of (virtual) compute nodes.
    pub nodes: u32,
    /// Nodes per IFS group.
    pub cn_per_ifs: u32,
}

impl LocalLayout {
    /// Create the directory tree under `root`.
    pub fn create(root: &Path, nodes: u32, cn_per_ifs: u32) -> Result<Self> {
        assert!(nodes >= 1 && cn_per_ifs >= 1);
        let layout = LocalLayout { root: root.to_path_buf(), nodes, cn_per_ifs };
        std::fs::create_dir_all(layout.gfs())?;
        for g in 0..layout.ifs_groups() {
            std::fs::create_dir_all(layout.ifs_staging(g))?;
            std::fs::create_dir_all(layout.ifs_data(g))?;
        }
        for n in 0..nodes {
            std::fs::create_dir_all(layout.lfs(n))?;
        }
        Ok(layout)
    }

    /// Number of IFS groups.
    pub fn ifs_groups(&self) -> u32 {
        self.nodes.div_ceil(self.cn_per_ifs)
    }

    /// IFS group of a node.
    pub fn group_of(&self, node: u32) -> u32 {
        node / self.cn_per_ifs
    }

    /// The GFS directory.
    pub fn gfs(&self) -> PathBuf {
        self.root.join("gfs")
    }

    /// An IFS group's staged-input data directory.
    pub fn ifs_data(&self, group: u32) -> PathBuf {
        self.root.join(format!("ifs/{group}/data"))
    }

    /// An IFS group's output staging directory (§5.2).
    pub fn ifs_staging(&self, group: u32) -> PathBuf {
        self.root.join(format!("ifs/{group}/staging"))
    }

    /// A node's LFS directory.
    pub fn lfs(&self, node: u32) -> PathBuf {
        self.root.join(format!("lfs/{node}"))
    }
}

/// State of one replica holder during a pipelined broadcast.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ReplicaState {
    /// Not yet copied.
    Pending,
    /// Copy complete; children may pull.
    Ready,
    /// Copy failed; children abort instead of waiting forever.
    Failed,
}

/// Distribute (replicate) a GFS file to every IFS group's data directory
/// following a spanning-tree schedule — the local equivalent of Chirp
/// `replicate`. Execution is **pipelined**: every scheduled copy runs on
/// its own thread and starts the moment its source replica is ready
/// (condvar handoff), so an early-landing replica feeds its children
/// without waiting for its round's stragglers. The schedule's `round`
/// numbers remain a dependency-order witness, not a barrier. Returns the
/// number of copies made.
pub fn distribute_to_ifs(layout: &LocalLayout, gfs_file: &str, shape: TreeShape) -> Result<u32> {
    let groups = layout.ifs_groups();
    let src = layout.gfs().join(gfs_file);
    anyhow::ensure!(src.is_file(), "no such GFS file: {}", src.display());
    // Replica holder i = IFS group i; holder 0 pulls from GFS.
    std::fs::copy(&src, layout.ifs_data(0).join(gfs_file))
        .with_context(|| "root pull from GFS")?;
    if groups == 1 {
        return Ok(1);
    }
    let schedule = shape.schedule(groups);
    let replicas: Vec<(Mutex<ReplicaState>, Condvar)> = (0..groups)
        .map(|g| {
            let state = if g == 0 { ReplicaState::Ready } else { ReplicaState::Pending };
            (Mutex::new(state), Condvar::new())
        })
        .collect();
    let errors: Mutex<Vec<anyhow::Error>> = Mutex::new(Vec::new());
    std::thread::scope(|scope| {
        for copy in &schedule {
            let src_path = layout.ifs_data(copy.src).join(gfs_file);
            let dst_path = layout.ifs_data(copy.dst).join(gfs_file);
            let (src_idx, dst_idx) = (copy.src as usize, copy.dst as usize);
            let replicas = &replicas;
            let errors = &errors;
            scope.spawn(move || {
                // Wait for the source replica to materialize.
                let src_ok = {
                    let (lock, cv) = &replicas[src_idx];
                    let mut state = lock.lock().unwrap();
                    while *state == ReplicaState::Pending {
                        state = cv.wait(state).unwrap();
                    }
                    *state == ReplicaState::Ready
                };
                let result = if src_ok {
                    std::fs::copy(&src_path, &dst_path).map(|_| ()).map_err(|e| {
                        anyhow::Error::from(e)
                            .context(format!("tree copy {}", dst_path.display()))
                    })
                } else {
                    Err(anyhow::anyhow!(
                        "replica {src_idx} failed upstream; copy to {dst_idx} skipped"
                    ))
                };
                // Record the root-cause error BEFORE publishing Failed:
                // children wake on the notify and push their synthetic
                // "skipped" errors, which must never shadow the real one
                // at the front of the list.
                let ok = result.is_ok();
                if let Err(e) = result {
                    errors.lock().unwrap().push(e);
                }
                let (lock, cv) = &replicas[dst_idx];
                let mut state = lock.lock().unwrap();
                *state = if ok { ReplicaState::Ready } else { ReplicaState::Failed };
                cv.notify_all();
            });
        }
    });
    let errs = errors.into_inner().unwrap();
    if let Some(e) = errs.into_iter().next() {
        return Err(e);
    }
    Ok(1 + schedule.len() as u32)
}

/// A task commits its output: the file moves from the node's LFS into its
/// IFS group's staging directory (the paper moves completed output
/// LFS→IFS, relying on rename atomicity within the staging FS).
///
/// This free function does **not** wake a running [`LocalCollector`];
/// prefer [`LocalCollector::commit`], which does. Files committed through
/// here are still picked up by the deadline / rescan backstop.
pub fn commit_output(layout: &LocalLayout, node: u32, name: &str) -> Result<u64> {
    let src = layout.lfs(node).join(name);
    let dst = layout.ifs_staging(layout.group_of(node)).join(name);
    let bytes = std::fs::metadata(&src)
        .with_context(|| format!("missing task output {}", src.display()))?
        .len();
    // Cross-filesystem rename can fail; fall back to copy+remove like the
    // paper's tar-based move.
    if std::fs::rename(&src, &dst).is_err() {
        std::fs::copy(&src, &dst)?;
        std::fs::remove_file(&src)?;
    }
    Ok(bytes)
}

/// Commit-side wakeup channel for one IFS group's collector thread.
#[derive(Default)]
struct GroupSignal {
    state: Mutex<GroupState>,
    cv: Condvar,
}

#[derive(Default)]
struct GroupState {
    /// Commits observed since the collector's last scan claim.
    pending: u64,
    /// Shutdown requested.
    stop: bool,
}

impl GroupSignal {
    fn notify_commit(&self) {
        self.state.lock().unwrap().pending += 1;
        self.cv.notify_one();
    }

    fn notify_stop(&self) {
        self.state.lock().unwrap().stop = true;
        self.cv.notify_all();
    }
}

/// Handle to a running threaded collector (one thread per IFS group).
pub struct LocalCollector {
    signals: Arc<Vec<GroupSignal>>,
    handles: Vec<std::thread::JoinHandle<Result<CollectorStats>>>,
    archives_written: Arc<AtomicU64>,
}

impl LocalCollector {
    /// Start collector threads over every IFS group. Each thread runs the
    /// §5.2 loop event-driven: sleep on the group's condvar, wake on
    /// commit (or at the `maxDelay` deadline), scan the staging dir once
    /// (batched `read_dir`), evaluate [`Policy`], and on a flush archive
    /// all staged files into one indexed archive in `gfs/` using the
    /// parallel-compression pipeline.
    pub fn start(layout: &LocalLayout, policy: Policy, compression: Compression) -> LocalCollector {
        let groups = layout.ifs_groups();
        let signals: Arc<Vec<GroupSignal>> =
            Arc::new((0..groups).map(|_| GroupSignal::default()).collect());
        let archives_written = Arc::new(AtomicU64::new(0));
        // Split the machine's parallelism across the per-group flush
        // pipelines so concurrent flushes do not oversubscribe.
        let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let flush_threads = (avail / groups.max(1) as usize).clamp(1, 8);
        let mut handles = Vec::new();
        for g in 0..groups {
            let staging = layout.ifs_staging(g);
            let gfs = layout.gfs();
            let policy = policy.clone();
            let signals = signals.clone();
            let counter = archives_written.clone();
            handles.push(std::thread::spawn(move || {
                collector_loop(
                    g,
                    &staging,
                    &gfs,
                    &policy,
                    compression,
                    &signals[g as usize],
                    &counter,
                    flush_threads,
                )
            }));
        }
        LocalCollector { signals, handles, archives_written }
    }

    /// Commit a task's output and wake the owning group's collector — the
    /// condvar fast path. Flush latency is then bounded by the policy
    /// evaluation plus archive IO, not a poll interval. `layout` must be
    /// the one this collector was started over (checked, since a
    /// mismatched layout would stage the file and then wake nobody).
    pub fn commit(&self, layout: &LocalLayout, node: u32, name: &str) -> Result<u64> {
        let group = layout.group_of(node) as usize;
        anyhow::ensure!(
            group < self.signals.len(),
            "node {node} is in IFS group {group}, but this collector serves {} group(s) — \
             commit called with a different layout than start()?",
            self.signals.len()
        );
        let bytes = commit_output(layout, node, name)?;
        self.signals[group].notify_commit();
        Ok(bytes)
    }

    /// Archives written so far (all groups).
    pub fn archives_written(&self) -> u64 {
        self.archives_written.load(Ordering::Relaxed)
    }

    /// Signal shutdown, final-drain every staging dir, and return merged
    /// stats.
    pub fn finish(self) -> Result<CollectorStats> {
        for signal in self.signals.iter() {
            signal.notify_stop();
        }
        let mut total = CollectorStats::default();
        for h in self.handles {
            let stats = h.join().map_err(|_| anyhow::anyhow!("collector thread panicked"))??;
            total.merge(&stats);
        }
        Ok(total)
    }
}

fn staged_files(staging: &Path) -> Result<Vec<(PathBuf, u64)>> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(staging)? {
        let entry = entry?;
        let meta = entry.metadata()?;
        if meta.is_file() {
            out.push((entry.path(), meta.len()));
        }
    }
    // Deterministic archive member order.
    out.sort();
    Ok(out)
}

#[allow(clippy::too_many_arguments)]
fn collector_loop(
    group: u32,
    staging: &Path,
    gfs: &Path,
    policy: &Policy,
    compression: Compression,
    signal: &GroupSignal,
    counter: &AtomicU64,
    flush_threads: usize,
) -> Result<CollectorStats> {
    let mut stats = CollectorStats::default();
    let started = Instant::now();
    let mut last_write = Duration::ZERO;
    let mut seq = 0u64;
    loop {
        // Claim every wakeup observed so far: a commit arriving after this
        // point re-arms the condvar instead of being lost to the scan.
        let stopping = {
            let mut state = signal.state.lock().unwrap();
            state.pending = 0;
            state.stop
        };
        let files = staged_files(staging)?;
        let buffered: u64 = files.iter().map(|(_, b)| b).sum();
        let since = SimTime::from_secs_f64((started.elapsed() - last_write).as_secs_f64());
        // Local staging is a real disk; free space is effectively
        // unbounded, so minFreeSpace never fires here (it is exercised in
        // the simulator). Use u64::MAX as "free".
        let reason = if stopping && !files.is_empty() {
            Some(FlushReason::Shutdown)
        } else {
            policy.should_flush(since, buffered, u64::MAX)
        };
        if let Some(reason) = reason {
            let archive_name = format!("out-g{group}-{seq:05}.cioar");
            seq += 1;
            let members: Vec<(String, PathBuf)> = files
                .iter()
                .map(|(path, _)| {
                    (path.file_name().unwrap().to_string_lossy().to_string(), path.clone())
                })
                .collect();
            let mut w = Writer::create(&gfs.join(&archive_name))?;
            w.add_paths_parallel(&members, compression, flush_threads)?;
            w.finish()?;
            for (path, _) in &files {
                std::fs::remove_file(path)?;
            }
            stats.record(reason, files.len() as u64, buffered);
            counter.fetch_add(1, Ordering::Relaxed);
            last_write = started.elapsed();
        }
        if stopping {
            return Ok(stats);
        }
        // Sleep until a commit wakes us, the maxDelay edge passes (only
        // meaningful while data is buffered — an empty staging dir never
        // deadline-flushes), or the unnotified-commit backstop expires.
        let has_backlog = reason.is_none() && buffered > 0;
        let wait = if has_backlog {
            let since_now =
                SimTime::from_secs_f64((started.elapsed() - last_write).as_secs_f64());
            policy.until_deadline(since_now).min(UNNOTIFIED_RESCAN)
        } else {
            UNNOTIFIED_RESCAN
        };
        let state = signal.state.lock().unwrap();
        if state.pending == 0 && !state.stop {
            let _unused = signal.cv.wait_timeout(state, wait).unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cio::archive::Reader;
    use crate::util::units::mib;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("cio-local-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn layout_creates_hierarchy() {
        let root = tmp("layout");
        let l = LocalLayout::create(&root, 8, 4).unwrap();
        assert_eq!(l.ifs_groups(), 2);
        assert_eq!(l.group_of(3), 0);
        assert_eq!(l.group_of(4), 1);
        assert!(l.gfs().is_dir());
        assert!(l.ifs_staging(1).is_dir());
        assert!(l.lfs(7).is_dir());
    }

    #[test]
    fn distribute_replicates_to_all_groups() {
        let root = tmp("dist");
        let l = LocalLayout::create(&root, 64, 8).unwrap(); // 8 groups
        std::fs::write(l.gfs().join("db.bin"), vec![42u8; 10_000]).unwrap();
        let copies = distribute_to_ifs(&l, "db.bin", TreeShape::Binomial).unwrap();
        assert_eq!(copies, 8, "1 GFS pull + 7 tree copies");
        for g in 0..8 {
            let replica = l.ifs_data(g).join("db.bin");
            assert_eq!(std::fs::read(replica).unwrap(), vec![42u8; 10_000], "group {g}");
        }
    }

    #[test]
    fn commit_moves_output_to_staging() {
        let root = tmp("commit");
        let l = LocalLayout::create(&root, 4, 4).unwrap();
        std::fs::write(l.lfs(2).join("t0.out"), b"result").unwrap();
        let bytes = commit_output(&l, 2, "t0.out").unwrap();
        assert_eq!(bytes, 6);
        assert!(!l.lfs(2).join("t0.out").exists());
        assert!(l.ifs_staging(0).join("t0.out").is_file());
    }

    #[test]
    fn collector_archives_staged_outputs() {
        let root = tmp("collector");
        let l = LocalLayout::create(&root, 8, 8).unwrap();
        // Tight policy so the flush happens fast in the test.
        let policy = Policy {
            max_delay: SimTime::from_secs(3600),
            max_data: 1024, // flush once >1 KiB buffered
            min_free_space: 0,
        };
        let collector = LocalCollector::start(&l, policy, Compression::None);
        // Simulate 16 tasks writing then committing outputs.
        for t in 0..16u32 {
            let node = t % 8;
            let name = format!("task-{t:03}.out");
            std::fs::write(l.lfs(node).join(&name), vec![t as u8; 256]).unwrap();
            collector.commit(&l, node, &name).unwrap();
        }
        // Wait for at least one policy-triggered flush, then stop.
        let deadline = Instant::now() + Duration::from_secs(5);
        while collector.archives_written() == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        let stats = collector.finish().unwrap();
        assert_eq!(stats.files, 16, "every committed output must be archived");
        assert!(stats.archives >= 1);
        assert!(stats.reasons[1] >= 1, "maxData flush expected: {:?}", stats.reasons);
        // Staging drained.
        assert!(staged_files(&l.ifs_staging(0)).unwrap().is_empty());
        // All archives readable, members intact, 16 total across archives.
        let mut member_count = 0;
        for entry in std::fs::read_dir(l.gfs()).unwrap() {
            let p = entry.unwrap().path();
            if p.extension().is_some_and(|e| e == "cioar") {
                let r = Reader::open(&p).unwrap();
                for e in r.entries() {
                    let data = r.extract(&e.name).unwrap();
                    assert_eq!(data.len(), 256);
                    member_count += 1;
                }
            }
        }
        assert_eq!(member_count, 16);
    }

    #[test]
    fn shutdown_drains_remaining() {
        let root = tmp("drain");
        let l = LocalLayout::create(&root, 2, 2).unwrap();
        let policy = Policy {
            max_delay: SimTime::from_secs(3600),
            max_data: mib(100), // never trips during the test
            min_free_space: 0,
        };
        let collector = LocalCollector::start(&l, policy, Compression::Deflate);
        std::fs::write(l.lfs(0).join("late.out"), b"late data").unwrap();
        collector.commit(&l, 0, "late.out").unwrap();
        let stats = collector.finish().unwrap();
        assert_eq!(stats.files, 1);
        assert_eq!(stats.reasons[3], 1, "shutdown drain: {:?}", stats.reasons);
    }

    #[test]
    fn unnotified_commits_still_collected() {
        // The free-function path (no condvar wakeup) must be drained by
        // the rescan backstop / shutdown, not lost.
        let root = tmp("unnotified");
        let l = LocalLayout::create(&root, 2, 2).unwrap();
        let policy = Policy {
            max_delay: SimTime::from_secs(3600),
            max_data: 64, // any commit exceeds this
            min_free_space: 0,
        };
        let collector = LocalCollector::start(&l, policy, Compression::None);
        std::fs::write(l.lfs(0).join("quiet.out"), vec![9u8; 512]).unwrap();
        commit_output(&l, 0, "quiet.out").unwrap(); // deliberately no notify
        let deadline = Instant::now() + Duration::from_secs(5);
        while collector.archives_written() == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(collector.archives_written() >= 1, "backstop rescan must find the file");
        let stats = collector.finish().unwrap();
        assert_eq!(stats.files, 1);
    }

    #[test]
    fn notified_flush_latency_is_not_poll_quantized() {
        // With maxData=1 every commit triggers a flush; the condvar path
        // must complete a *typical* round trip well under the old 5 ms
        // poll floor. Assert on the median so one scheduler stall on a
        // loaded CI runner cannot flake the test.
        let root = tmp("latency");
        let l = LocalLayout::create(&root, 1, 1).unwrap();
        let policy =
            Policy { max_delay: SimTime::from_secs(3600), max_data: 1, min_free_space: 0 };
        let collector = LocalCollector::start(&l, policy, Compression::None);
        let rounds = 20u64;
        let mut latencies = Vec::new();
        for i in 0..rounds {
            let name = format!("r{i:02}.out");
            std::fs::write(l.lfs(0).join(&name), vec![1u8; 128]).unwrap();
            let t0 = Instant::now();
            collector.commit(&l, 0, &name).unwrap();
            let deadline = Instant::now() + Duration::from_secs(5);
            while collector.archives_written() <= i && Instant::now() < deadline {
                std::thread::yield_now();
            }
            latencies.push(t0.elapsed());
        }
        let stats = collector.finish().unwrap();
        assert_eq!(stats.files, rounds);
        latencies.sort();
        let median = latencies[latencies.len() / 2];
        assert!(
            median < Duration::from_millis(5),
            "median commit->flush latency {median:?}; condvar path should beat the \
             old 5 ms poll quantum"
        );
    }
}
