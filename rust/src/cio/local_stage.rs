//! Real-bytes multi-stage runtime (§5.3 / Figure 17): execute a workflow
//! DAG over a [`LocalLayout`] directory tree with inter-stage IFS
//! retention.
//!
//! The accounting structs in [`crate::cio::stage`] ([`StageGraph`],
//! [`IfsCache`]) model the paper's dataflow synchronization and retention
//! policy; this module wires them into the real-bytes runtime:
//!
//! * [`StageRunner`] runs each stage's tasks on worker threads. Task
//!   outputs commit through a per-stage [`LocalCollector`] whose flushes
//!   land on `gfs/` **and are retained** in the owning group's
//!   `ifs/<group>/data/` directory under [`GroupCache`] bounded-LRU
//!   control (eviction unlinks the retained file).
//! * Stage N+1's tasks open stage N's output archives via
//!   [`crate::cio::archive::Reader`] random access — archive-as-input —
//!   resolving each archive through the task's group cache: an
//!   [`CacheOutcome::IfsHit`] reads the retained copy in place; a
//!   [`CacheOutcome::GfsMiss`] pays the full GFS round trip (the archive
//!   is re-staged from `gfs/` into the group's data dir, read-through,
//!   exactly the §5.3 fallback) before the read proceeds.
//!
//! Figure 17's stage-2 ablation is this hit/miss difference on real
//! bytes: a hit reads the archive once from fast local storage, a miss
//! pays an extra full-archive copy from the central store first. The
//! `stage2_ifs_hit` / `stage2_gfs_miss` cases in `perf_micro` measure it;
//! `examples/multistage_workflow.rs` runs the whole 3-stage chain.

use crate::cio::archive::{Compression, Reader};
use crate::cio::collector::{CollectorStats, Policy};
use crate::cio::local::{publish_copy, CollectorOptions, LocalCollector, LocalLayout};
use crate::cio::placement::PlacementPolicy;
use crate::cio::stage::{CacheOutcome, IfsCache, StageGraph};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Point-in-time counters of one group's retention cache.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheSnapshot {
    /// Lookups served from the IFS retained copy.
    pub hits: u64,
    /// Lookups that fell back to GFS.
    pub misses: u64,
    /// Retained archives evicted (files unlinked) to bound capacity.
    pub evictions: u64,
    /// Bytes currently retained.
    pub used: u64,
}

/// One IFS group's on-disk retention: the [`IfsCache`] accounting plus the
/// real archive files it governs in `ifs/<group>/data/`. All mutation
/// (retain, read-through fill, eviction unlink) happens under one lock,
/// so a hit can never observe a half-evicted or half-published file.
/// Correctness over concurrency: a miss's read-through copy runs under
/// the lock, serializing that group's fills (which also dedupes
/// concurrent misses of the same archive into one copy plus hits);
/// moving the copy outside the lock behind an in-flight map is a known
/// follow-up (see ROADMAP).
pub struct GroupCache {
    data_dir: PathBuf,
    inner: Mutex<IfsCache>,
}

impl GroupCache {
    /// Retention for `group` of `layout`, bounded by `capacity` bytes.
    pub fn new(layout: &LocalLayout, group: u32, capacity: u64) -> GroupCache {
        GroupCache { data_dir: layout.ifs_data(group), inner: Mutex::new(IfsCache::new(capacity)) }
    }

    /// One cache per IFS group of `layout`, ready for
    /// [`CollectorOptions::retention`].
    pub fn per_group(layout: &LocalLayout, capacity: u64) -> Arc<Vec<GroupCache>> {
        Arc::new((0..layout.ifs_groups()).map(|g| GroupCache::new(layout, g, capacity)).collect())
    }

    /// Retain a copy of `src` (an archive just flushed to GFS) as `name`
    /// in this group's data dir, evicting LRU retained files to make
    /// room. Returns `Ok(false)` when the archive is larger than the
    /// whole cache and was not retained (it stays GFS-only, per §5.3).
    pub fn retain(&self, src: &std::path::Path, name: &str) -> Result<bool> {
        let bytes = std::fs::metadata(src)
            .with_context(|| format!("retaining {}", src.display()))?
            .len();
        let mut cache = self.inner.lock().unwrap();
        let Some(victims) = cache.put_evicting(name, bytes) else {
            return Ok(false);
        };
        for victim in &victims {
            let _ = std::fs::remove_file(self.data_dir.join(victim));
        }
        if let Err(e) = publish_copy(src, &self.data_dir.join(name)) {
            // Keep accounting honest: the copy never landed.
            cache.remove(name);
            return Err(e.context(format!("retaining archive {name} on IFS")));
        }
        Ok(true)
    }

    /// Open archive `name` for a stage task: the retained copy on a hit;
    /// on a miss, pull the archive from `gfs_dir` into the data dir
    /// (read-through — the §5.3 re-stage from central storage, and the
    /// cost a miss pays), retain it, then open. Oversized archives are
    /// read from GFS directly without retention.
    pub fn open_archive(
        &self,
        gfs_dir: &std::path::Path,
        name: &str,
    ) -> Result<(Reader, CacheOutcome)> {
        let mut cache = self.inner.lock().unwrap();
        match cache.get(name) {
            CacheOutcome::IfsHit => {
                let reader = Reader::open(&self.data_dir.join(name))
                    .with_context(|| format!("opening retained archive {name}"))?;
                Ok((reader, CacheOutcome::IfsHit))
            }
            CacheOutcome::GfsMiss => {
                let gfs_path = gfs_dir.join(name);
                let bytes = std::fs::metadata(&gfs_path)
                    .with_context(|| format!("no archive {name} on GFS"))?
                    .len();
                match cache.put_evicting(name, bytes) {
                    Some(victims) => {
                        for victim in &victims {
                            let _ = std::fs::remove_file(self.data_dir.join(victim));
                        }
                        let retained = self.data_dir.join(name);
                        if let Err(e) = publish_copy(&gfs_path, &retained) {
                            cache.remove(name);
                            return Err(e.context(format!("re-staging archive {name} to IFS")));
                        }
                        Ok((Reader::open(&retained)?, CacheOutcome::GfsMiss))
                    }
                    // Larger than the whole cache: read from GFS in place.
                    None => Ok((Reader::open(&gfs_path)?, CacheOutcome::GfsMiss)),
                }
            }
        }
    }

    /// Current counters.
    pub fn snapshot(&self) -> CacheSnapshot {
        let cache = self.inner.lock().unwrap();
        CacheSnapshot {
            hits: cache.hits(),
            misses: cache.misses(),
            evictions: cache.evictions(),
            used: cache.used(),
        }
    }

    /// Is `name` currently retained (no recency/counter side effects)?
    pub fn contains(&self, name: &str) -> bool {
        self.inner.lock().unwrap().contains(name)
    }
}

/// Delete every `<prefix>-g*.cioar` in `dir` (stale stage artifacts from
/// a previous run on the same layout). Other files — staged inputs,
/// other stages' archives — are untouched.
fn clear_matching(dir: &std::path::Path, prefix: &str) -> Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name().to_string_lossy().to_string();
        if name.starts_with(&format!("{prefix}-g")) && name.ends_with(".cioar") {
            std::fs::remove_file(entry.path())
                .with_context(|| format!("clearing stale stage archive {name}"))?;
        }
    }
    Ok(())
}

/// Parse the owning IFS group out of a collector archive name
/// (`<prefix>-g<group>-<seq>.cioar`).
pub fn archive_group(name: &str) -> Option<u32> {
    let stem = name.strip_suffix(".cioar")?;
    let mut parts = stem.rsplitn(3, '-');
    let _seq = parts.next()?;
    parts.next()?.strip_prefix('g')?.parse().ok()
}

/// Canonical output member name for task `task` of stage `stage_idx`
/// named `stage_name` — what [`StageRunner`] commits, and therefore the
/// member name a downstream stage asks [`StageInput::read_member`] for.
pub fn task_output_name(stage_idx: usize, stage_name: &str, task: u32) -> String {
    format!("s{stage_idx}-{stage_name}-{task:05}.out")
}

/// Configuration for a [`StageRunner`].
#[derive(Clone)]
pub struct StageRunnerConfig {
    /// §5.2 flush policy for every stage's collector.
    pub policy: Policy,
    /// Archive compression.
    pub compression: Compression,
    /// Per-group retention capacity in bytes (bounds each [`GroupCache`]).
    pub cache_capacity: u64,
    /// Worker threads per stage (tasks are pulled off a shared counter).
    pub threads: usize,
}

impl StageRunnerConfig {
    /// Derive the retention capacity from the placement policy's IFS
    /// sizing ([`PlacementPolicy::retention_capacity`]).
    pub fn with_placement(
        policy: Policy,
        compression: Compression,
        placement: &PlacementPolicy,
        threads: usize,
    ) -> StageRunnerConfig {
        StageRunnerConfig {
            policy,
            compression,
            cache_capacity: placement.retention_capacity(),
            threads,
        }
    }
}

/// One stage's executable body: `tasks` tasks, each mapping
/// `(task_index, upstream input)` to its output bytes. Bodies run on
/// worker threads, hence `Sync`.
pub struct StageExec<'a> {
    /// Number of tasks in this stage.
    pub tasks: u32,
    /// The task body.
    pub run: &'a (dyn Fn(u32, &StageInput<'_>) -> Result<Vec<u8>> + Sync),
}

/// Read access to the upstream stages' output archives for one task.
/// Every archive resolve goes through the task's group cache:
/// hit → retained IFS copy, miss → GFS round trip (re-staged locally).
pub struct StageInput<'a> {
    gfs: PathBuf,
    caches: &'a [GroupCache],
    /// The reading task's IFS group.
    group: u32,
    /// member name → (archive name, producing group).
    members: &'a BTreeMap<String, (String, u32)>,
    /// upstream (archive name, producing group), sorted by name.
    archives: &'a [(String, u32)],
}

impl StageInput<'_> {
    /// Upstream archives as `(name, producing group)`.
    pub fn archives(&self) -> &[(String, u32)] {
        self.archives
    }

    /// All upstream member names (sorted).
    pub fn members(&self) -> impl Iterator<Item = &str> {
        self.members.keys().map(|s| s.as_str())
    }

    /// The archive holding `member`, if any upstream stage produced it.
    pub fn member_archive(&self, member: &str) -> Option<&str> {
        self.members.get(member).map(|(a, _)| a.as_str())
    }

    /// The reading task's IFS group.
    pub fn group(&self) -> u32 {
        self.group
    }

    /// Open an upstream archive through this task's group cache.
    pub fn open_archive(&self, name: &str) -> Result<(Reader, CacheOutcome)> {
        self.caches[self.group as usize].open_archive(&self.gfs, name)
    }

    /// Read one upstream member: find its archive, open it (IFS hit or
    /// GFS miss), extract the member by random access.
    ///
    /// A retained copy can be evicted (its file unlinked) between the
    /// open and the extract — e.g. this stage's own collector retaining a
    /// new archive under a tight cache. The GFS copy is canonical and
    /// never evicted, so a failed hit-read falls back to a direct GFS
    /// read and reports the honest [`CacheOutcome::GfsMiss`].
    pub fn read_member(&self, member: &str) -> Result<(Vec<u8>, CacheOutcome)> {
        let (archive, _owner) = self
            .members
            .get(member)
            .with_context(|| format!("no upstream stage produced member {member:?}"))?;
        let (reader, outcome) = self.open_archive(archive)?;
        match reader.extract(member) {
            Ok(bytes) => Ok((bytes, outcome)),
            Err(_) if outcome == CacheOutcome::IfsHit => {
                let reader = Reader::open(&self.gfs.join(archive))?;
                Ok((reader.extract(member)?, CacheOutcome::GfsMiss))
            }
            Err(e) => Err(e),
        }
    }
}

/// Per-stage outcome in a [`WorkflowReport`].
#[derive(Debug, Clone, Default)]
pub struct StageStats {
    /// Stage name (from the [`StageGraph`]).
    pub name: String,
    /// Tasks executed.
    pub tasks: u32,
    /// The stage collector's flush statistics.
    pub collector: CollectorStats,
    /// Archives this stage produced on GFS, sorted.
    pub archives: Vec<String>,
    /// Upstream archive resolves served from IFS retention, as accounted
    /// by the group caches. A read that loses the eviction race after a
    /// hit-open is served from GFS (and its task sees
    /// [`CacheOutcome::GfsMiss`]) but still counts as a hit here — the
    /// per-read outcome is the effective source of truth.
    pub ifs_hits: u64,
    /// Upstream archive resolves that paid the GFS round trip.
    pub gfs_misses: u64,
    /// Wall-clock seconds for the stage (tasks + final drain).
    pub elapsed_s: f64,
}

/// Whole-workflow outcome.
#[derive(Debug, Clone, Default)]
pub struct WorkflowReport {
    /// Per-stage stats in completion order.
    pub stages: Vec<StageStats>,
}

impl WorkflowReport {
    /// Total IFS hits across stages.
    pub fn ifs_hits(&self) -> u64 {
        self.stages.iter().map(|s| s.ifs_hits).sum()
    }

    /// Total GFS misses across stages.
    pub fn gfs_misses(&self) -> u64 {
        self.stages.iter().map(|s| s.gfs_misses).sum()
    }

    /// Workflow-wide retention hit rate in [0,1] (0 when nothing read).
    pub fn hit_rate(&self) -> f64 {
        let total = self.ifs_hits() + self.gfs_misses();
        if total == 0 {
            0.0
        } else {
            self.ifs_hits() as f64 / total as f64
        }
    }
}

/// Executes a [`StageGraph`] workflow over a [`LocalLayout`] with §5.3
/// inter-stage IFS retention. See the module docs for the data flow.
pub struct StageRunner {
    layout: LocalLayout,
    graph: StageGraph,
    caches: Arc<Vec<GroupCache>>,
    config: StageRunnerConfig,
}

/// What the runner remembers about a completed stage's outputs.
struct ProducedArchives {
    /// (archive name, producing group), sorted by name.
    archives: Vec<(String, u32)>,
    /// member name → (archive name, producing group).
    members: BTreeMap<String, (String, u32)>,
}

impl StageRunner {
    /// Build a runner; one [`GroupCache`] per IFS group, each bounded by
    /// `config.cache_capacity`.
    pub fn new(layout: LocalLayout, graph: StageGraph, config: StageRunnerConfig) -> StageRunner {
        let caches = GroupCache::per_group(&layout, config.cache_capacity);
        StageRunner { layout, graph, caches, config }
    }

    /// The directory layout this runner executes over.
    pub fn layout(&self) -> &LocalLayout {
        &self.layout
    }

    /// The per-group retention caches (inspection / warmup).
    pub fn caches(&self) -> &[GroupCache] {
        &self.caches
    }

    /// Execute the whole workflow: stages run as the [`StageGraph`] makes
    /// them ready (dataflow synchronization — a stage runs only after
    /// every stage it reads from completed), each over `execs[i].tasks`
    /// tasks. `execs` must have one entry per graph stage.
    pub fn run(&mut self, execs: &[StageExec<'_>]) -> Result<WorkflowReport> {
        anyhow::ensure!(
            execs.len() == self.graph.len(),
            "{} stage bodies for a {}-stage graph",
            execs.len(),
            self.graph.len()
        );
        let mut produced: Vec<Option<ProducedArchives>> = Vec::new();
        produced.resize_with(self.graph.len(), || None);
        let mut report = WorkflowReport::default();
        while !self.graph.all_done() {
            let ready = self.graph.ready_stages();
            anyhow::ensure!(!ready.is_empty(), "workflow stalled (graph bug)");
            for i in ready {
                // Upstream input = the union of every dependency's output
                // archives (rule 3: those writers have all completed).
                let mut archives: Vec<(String, u32)> = Vec::new();
                let mut members: BTreeMap<String, (String, u32)> = BTreeMap::new();
                let deps = self.graph.stage(i).deps.clone();
                for &dep in &deps {
                    let p = produced[dep].as_ref().expect("dep completed before reader");
                    archives.extend(p.archives.iter().cloned());
                    for (m, loc) in &p.members {
                        members.insert(m.clone(), loc.clone());
                    }
                }
                archives.sort();
                let (stats, out) = self.run_stage(i, &execs[i], &archives, &members)?;
                report.stages.push(stats);
                produced[i] = Some(out);
                self.graph.complete(i);
            }
        }
        Ok(report)
    }

    /// Run one stage: collector up (per-stage archive prefix, retention
    /// into the group caches), tasks over worker threads, final drain,
    /// then index this stage's archives for downstream readers.
    fn run_stage(
        &self,
        stage_idx: usize,
        exec: &StageExec<'_>,
        upstream_archives: &[(String, u32)],
        upstream_members: &BTreeMap<String, (String, u32)>,
    ) -> Result<(StageStats, ProducedArchives)> {
        let stage_name = self.graph.stage(stage_idx).name.clone();
        let t0 = Instant::now();
        let before: Vec<CacheSnapshot> = self.caches.iter().map(|c| c.snapshot()).collect();
        let prefix = format!("s{stage_idx}");
        let gfs = self.layout.gfs();
        // Fresh-run semantics: stage archives are derived artifacts. A
        // previous (possibly failed) run on this layout may have left
        // `s<i>-g*` archives behind with other sequence numbers; the
        // post-stage index scan must never serve those stale bytes as
        // this run's output, so clear them before the collector starts.
        // The same goes for stale *retained* copies in the IFS data dirs:
        // this run's (empty-accounted) caches would never evict them, so
        // left in place they would leak past the cache_capacity bound.
        clear_matching(&gfs, &prefix)?;
        for g in 0..self.layout.ifs_groups() {
            clear_matching(&self.layout.ifs_data(g), &prefix)?;
        }
        let collector = LocalCollector::start_with(
            &self.layout,
            self.config.policy.clone(),
            self.config.compression,
            CollectorOptions {
                archive_prefix: Some(prefix.clone()),
                retention: Some(self.caches.clone()),
            },
        )?;

        let next = AtomicU32::new(0);
        let abort = AtomicBool::new(false);
        let errors: Mutex<Vec<anyhow::Error>> = Mutex::new(Vec::new());
        let workers = self.config.threads.max(1).min(exec.tasks.max(1) as usize);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                let next = &next;
                let abort = &abort;
                let errors = &errors;
                let collector = &collector;
                let gfs = &gfs;
                let stage_name = &stage_name;
                scope.spawn(move || {
                    loop {
                        let t = next.fetch_add(1, Ordering::Relaxed);
                        if t >= exec.tasks || abort.load(Ordering::Relaxed) {
                            return;
                        }
                        let node = t % self.layout.nodes;
                        let input = StageInput {
                            gfs: gfs.clone(),
                            caches: &self.caches,
                            group: self.layout.group_of(node),
                            members: upstream_members,
                            archives: upstream_archives,
                        };
                        let result = (exec.run)(t, &input).and_then(|bytes| {
                            let name = task_output_name(stage_idx, stage_name, t);
                            std::fs::write(self.layout.lfs(node).join(&name), &bytes)
                                .with_context(|| format!("writing task output {name}"))?;
                            collector.commit(&self.layout, node, &name)?;
                            Ok(())
                        });
                        if let Err(e) = result {
                            abort.store(true, Ordering::Relaxed);
                            errors
                                .lock()
                                .unwrap()
                                .push(e.context(format!("stage {stage_name}, task {t}")));
                            return;
                        }
                    }
                });
            }
        });
        // Always drain the collector, even on task failure, so staged
        // outputs of the successful tasks are not abandoned.
        let collector_stats = collector.finish()?;
        if let Some(e) = errors.into_inner().unwrap().into_iter().next() {
            return Err(e);
        }

        // Index what this stage produced for downstream stages. The GFS
        // copy is canonical; only the index (a footer read) is touched.
        let mut archives: Vec<(String, u32)> = Vec::new();
        let mut members: BTreeMap<String, (String, u32)> = BTreeMap::new();
        for entry in std::fs::read_dir(&gfs)? {
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().to_string();
            if !name.starts_with(&format!("{prefix}-g")) || !name.ends_with(".cioar") {
                continue;
            }
            let group = archive_group(&name)
                .with_context(|| format!("unparseable archive name {name:?}"))?;
            let reader = Reader::open(&entry.path())?;
            for e in reader.entries() {
                members.insert(e.name.clone(), (name.clone(), group));
            }
            archives.push((name, group));
        }
        archives.sort();

        let after: Vec<CacheSnapshot> = self.caches.iter().map(|c| c.snapshot()).collect();
        let ifs_hits: u64 = before.iter().zip(&after).map(|(b, a)| a.hits - b.hits).sum();
        let gfs_misses: u64 = before.iter().zip(&after).map(|(b, a)| a.misses - b.misses).sum();
        let stats = StageStats {
            name: stage_name,
            tasks: exec.tasks,
            collector: collector_stats,
            archives: archives.iter().map(|(n, _)| n.clone()).collect(),
            ifs_hits,
            gfs_misses,
            elapsed_s: t0.elapsed().as_secs_f64(),
        };
        Ok((stats, ProducedArchives { archives, members }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::{mib, SimTime};

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("cio-stage-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn write_archive(dir: &std::path::Path, name: &str, members: &[(&str, &[u8])]) {
        let mut w = crate::cio::archive::Writer::create(&dir.join(name)).unwrap();
        for (m, data) in members {
            w.add(m, data, Compression::None).unwrap();
        }
        w.finish().unwrap();
    }

    #[test]
    fn archive_group_parses_collector_names() {
        assert_eq!(archive_group("out-g3-00017.cioar"), Some(3));
        assert_eq!(archive_group("s1-g0-00000.cioar"), Some(0));
        assert_eq!(archive_group("s1-extra-g12-00001.cioar"), Some(12));
        assert_eq!(archive_group("random.cioar"), None);
        assert_eq!(archive_group("out-g3-00017.tar"), None);
    }

    #[test]
    fn group_cache_retain_hit_and_readthrough_miss() {
        let root = tmp("gc");
        let layout = LocalLayout::create(&root, 2, 2).unwrap();
        write_archive(&layout.gfs(), "a.cioar", &[("m0", b"alpha")]);
        write_archive(&layout.gfs(), "b.cioar", &[("m1", b"beta")]);
        let cache = GroupCache::new(&layout, 0, mib(16));

        // Explicit retention (the collector path) -> hit.
        assert!(cache.retain(&layout.gfs().join("a.cioar"), "a.cioar").unwrap());
        let (r, outcome) = cache.open_archive(&layout.gfs(), "a.cioar").unwrap();
        assert_eq!(outcome, CacheOutcome::IfsHit);
        assert_eq!(r.extract("m0").unwrap(), b"alpha");

        // Never retained -> miss, read-through fill, then hit.
        let (r, outcome) = cache.open_archive(&layout.gfs(), "b.cioar").unwrap();
        assert_eq!(outcome, CacheOutcome::GfsMiss);
        assert_eq!(r.extract("m1").unwrap(), b"beta");
        assert!(layout.ifs_data(0).join("b.cioar").is_file(), "read-through must fill");
        let (_, outcome) = cache.open_archive(&layout.gfs(), "b.cioar").unwrap();
        assert_eq!(outcome, CacheOutcome::IfsHit);

        let snap = cache.snapshot();
        assert_eq!((snap.hits, snap.misses), (2, 1));
    }

    #[test]
    fn group_cache_eviction_unlinks_files() {
        let root = tmp("gc-evict");
        let layout = LocalLayout::create(&root, 1, 1).unwrap();
        let payload = vec![7u8; 4096];
        write_archive(&layout.gfs(), "x.cioar", &[("m", &payload)]);
        write_archive(&layout.gfs(), "y.cioar", &[("m", &payload)]);
        let x_bytes = std::fs::metadata(layout.gfs().join("x.cioar")).unwrap().len();
        // Capacity fits exactly one archive.
        let cache = GroupCache::new(&layout, 0, x_bytes + 16);
        assert!(cache.retain(&layout.gfs().join("x.cioar"), "x.cioar").unwrap());
        assert!(layout.ifs_data(0).join("x.cioar").is_file());
        assert!(cache.retain(&layout.gfs().join("y.cioar"), "y.cioar").unwrap());
        assert!(!layout.ifs_data(0).join("x.cioar").exists(), "evicted file must be unlinked");
        assert!(cache.contains("y.cioar") && !cache.contains("x.cioar"));
        assert_eq!(cache.snapshot().evictions, 1);
    }

    #[test]
    fn oversized_archive_read_from_gfs_without_retention() {
        let root = tmp("gc-big");
        let layout = LocalLayout::create(&root, 1, 1).unwrap();
        write_archive(&layout.gfs(), "big.cioar", &[("m", &vec![1u8; 8192])]);
        let cache = GroupCache::new(&layout, 0, 64); // tiny
        assert!(!cache.retain(&layout.gfs().join("big.cioar"), "big.cioar").unwrap());
        let (r, outcome) = cache.open_archive(&layout.gfs(), "big.cioar").unwrap();
        assert_eq!(outcome, CacheOutcome::GfsMiss);
        assert_eq!(r.extract("m").unwrap().len(), 8192);
        assert!(!layout.ifs_data(0).join("big.cioar").exists(), "oversized: no fill");
    }

    #[test]
    fn three_stage_chain_runs_with_retention_hits() {
        let root = tmp("runner");
        let layout = LocalLayout::create(&root, 4, 2).unwrap(); // 2 groups
        let graph = StageGraph::chain(&["produce", "transform", "reduce"]);
        let config = StageRunnerConfig {
            policy: Policy {
                max_delay: SimTime::from_secs(3600),
                max_data: 2048,
                min_free_space: 0,
            },
            compression: Compression::None,
            cache_capacity: mib(64),
            threads: 4,
        };
        let mut runner = StageRunner::new(layout, graph, config);
        let tasks = 16u32;
        let produce = |t: u32, _input: &StageInput<'_>| -> Result<Vec<u8>> {
            Ok(vec![t as u8; 512])
        };
        let transform = |t: u32, input: &StageInput<'_>| -> Result<Vec<u8>> {
            let upstream = task_output_name(0, "produce", t);
            let (bytes, _outcome) = input.read_member(&upstream)?;
            anyhow::ensure!(bytes == vec![t as u8; 512], "stage-1 bytes corrupt for task {t}");
            let sum: u64 = bytes.iter().map(|&b| b as u64).sum();
            Ok(sum.to_le_bytes().to_vec())
        };
        let reduce = |_t: u32, input: &StageInput<'_>| -> Result<Vec<u8>> {
            let mut total = 0u64;
            for t in 0..tasks {
                let (bytes, _) = input.read_member(&task_output_name(1, "transform", t))?;
                total += u64::from_le_bytes(bytes.as_slice().try_into()?);
            }
            Ok(total.to_le_bytes().to_vec())
        };
        let report = runner
            .run(&[
                StageExec { tasks, run: &produce },
                StageExec { tasks, run: &transform },
                StageExec { tasks: 1, run: &reduce },
            ])
            .unwrap();
        assert_eq!(report.stages.len(), 3);
        assert_eq!(report.stages[0].collector.files, tasks as u64);
        assert!(report.stages[0].collector.retained >= 1, "stage-1 archives must be retained");
        assert!(report.stages[1].ifs_hits > 0, "stage 2 must hit the IFS cache");
        assert!(report.ifs_hits() > 0 && report.hit_rate() > 0.0);
        // The final reduce output exists and holds the expected total:
        // sum over t of t*512.
        let expected: u64 = (0..tasks as u64).map(|t| t * 512).sum();
        let final_archives = &report.stages[2].archives;
        assert_eq!(final_archives.len(), 1, "one reduce task -> one archive");
        let r = Reader::open(&runner.layout().gfs().join(&final_archives[0])).unwrap();
        let bytes = r.extract(&task_output_name(2, "reduce", 0)).unwrap();
        assert_eq!(u64::from_le_bytes(bytes.as_slice().try_into().unwrap()), expected);
    }

    #[test]
    fn task_error_aborts_stage_but_drains_collector() {
        let root = tmp("runner-err");
        let layout = LocalLayout::create(&root, 2, 2).unwrap();
        let graph = StageGraph::chain(&["only"]);
        let config = StageRunnerConfig {
            policy: Policy {
                max_delay: SimTime::from_secs(3600),
                max_data: mib(100),
                min_free_space: 0,
            },
            compression: Compression::None,
            cache_capacity: mib(4),
            threads: 1,
        };
        let mut runner = StageRunner::new(layout, graph, config);
        let body = |t: u32, _input: &StageInput<'_>| -> Result<Vec<u8>> {
            anyhow::ensure!(t != 3, "task 3 exploded");
            Ok(vec![0u8; 16])
        };
        let err = runner.run(&[StageExec { tasks: 8, run: &body }]).unwrap_err();
        assert!(format!("{err:#}").contains("task 3 exploded"), "{err:#}");
    }
}
