"""AOT lowering: JAX model (L2, calling the Pallas L1 kernel) → HLO text.

Run once at build time (`make artifacts`); the Rust coordinator loads the
result via `HloModuleProto::from_text_file` + PJRT and Python never runs
on the request path.

HLO **text** is the interchange format, not `.serialize()`: jax ≥ 0.5
emits HloModuleProtos with 64-bit instruction ids which the pinned
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    cd python && python -m compile.aot --out ../artifacts/dock_score.hlo.txt \
        [--batch 64 --atoms 32 --features 8]

Writes `<out>` plus a sibling `<out minus .hlo.txt>.meta` with the shape
metadata the Rust side validates against.
"""

import argparse
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_score_batch(batch: int, atoms: int, features: int) -> str:
    """Lower model.score_batch for the given static shapes."""
    lig = jax.ShapeDtypeStruct((batch, atoms, 4), jax.numpy.float32)
    grid = jax.ShapeDtypeStruct((atoms, features), jax.numpy.float32)
    weights = jax.ShapeDtypeStruct((features,), jax.numpy.float32)
    lowered = jax.jit(model.score_batch).lower(lig, grid, weights)
    return to_hlo_text(lowered)


def lower_screen(batch: int, atoms: int, features: int, top_k: int) -> str:
    """Lower model.screen (scores + fused top-k selection) for static
    shapes — the stage-2 'select' step as a single compiled graph."""
    lig = jax.ShapeDtypeStruct((batch, atoms, 4), jax.numpy.float32)
    grid = jax.ShapeDtypeStruct((atoms, features), jax.numpy.float32)
    weights = jax.ShapeDtypeStruct((features,), jax.numpy.float32)
    lowered = jax.jit(lambda l, g, w: model.screen(l, g, w, top_k=top_k)).lower(
        lig, grid, weights
    )
    return to_hlo_text(lowered)


def meta_text(batch: int, atoms: int, features: int, top_k=None) -> str:
    text = (
        "# shapes baked into the sibling .hlo.txt artifact\n"
        f"batch = {batch}\n"
        f"atoms = {atoms}\n"
        f"features = {features}\n"
    )
    if top_k is not None:
        text += f"top_k = {top_k}\n"
    return text


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts/dock_score.hlo.txt")
    p.add_argument("--model", choices=["score", "screen"], default="score")
    p.add_argument("--batch", type=int, default=64)
    p.add_argument("--atoms", type=int, default=32)
    p.add_argument("--features", type=int, default=8)
    p.add_argument("--top-k", type=int, default=16)
    args = p.parse_args(argv)

    if args.model == "screen":
        text = lower_screen(args.batch, args.atoms, args.features, args.top_k)
        meta = meta_text(args.batch, args.atoms, args.features, args.top_k)
    else:
        text = lower_score_batch(args.batch, args.atoms, args.features)
        meta = meta_text(args.batch, args.atoms, args.features)
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        f.write(text)
    meta_path = args.out
    if meta_path.endswith(".hlo.txt"):
        meta_path = meta_path[: -len(".hlo.txt")] + ".meta"
    else:
        meta_path += ".meta"
    with open(meta_path, "w") as f:
        f.write(meta)
    print(f"wrote {len(text)} chars to {args.out} (+ {os.path.basename(meta_path)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
