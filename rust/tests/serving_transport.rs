//! Integration: the PR-7 serving tier — runners serving each other's
//! retention over the wire transport.
//!
//! * In-process socket serving: a `GroupCache` behind a
//!   [`TransportServer`] serves a peer cache's whole-archive fills and
//!   record-range reads byte-exact with the GFS tier never touched.
//! * Cross-process serving: a real second process (`cio-serve`) warms a
//!   group's retention on a shared layout root; this process's runner
//!   seeds its routing directory from the peer's manifest
//!   ([`bootstrap_peer_directory`]), registers a [`SocketTransport`],
//!   and must resolve reads with **zero GFS misses**.
//! * The wire fault matrix riding the PR-6 chain: a mid-frame
//!   connection drop is a retryable torn transfer that re-routes, and a
//!   stalled peer blows the per-source deadline, re-routes, and trips
//!   the quarantine breaker — byte-exact data and no wedged fill latch
//!   either way.
//! * The PR-8 integrity and lifecycle cells: a frame whose payload was
//!   flipped in flight fails its frame CRC and re-routes byte-exact;
//!   a hard-killed peer process is detected through the stale pooled
//!   connection, its fills re-route, and the [`PeerMonitor`]'s missed
//!   heartbeats expire its liveness lease — withdrawing the dead
//!   peer's whole advertised retention in one step and gating even the
//!   producer fallback until it comes back.
//! * The PR-10 cells: a saturated server answers over-cap connections
//!   with a typed retryable `BUSY` (clients back off and drain through —
//!   no wedged latch, no unbounded thread pile), and the availability
//!   manager heals a hard-killed peer — the lease expiry orphans its
//!   popular archives, rate-limited repair pushes re-replicate them, and
//!   a third runner's reads come back with **zero GFS misses** where the
//!   repair-disabled control pays one per archive.

use cio::cio::archive::{Compression, Reader, Writer};
use cio::cio::directory::RetentionDirectory;
use cio::cio::fault::{FaultAction, FaultInjector, OpClass, RetryPolicy};
use cio::cio::local::LocalLayout;
use cio::cio::local_stage::{
    bootstrap_peer_directory, ClusterRecordSource, GroupCache, PeerMonitor,
    RunnerRepairExecutor,
};
use cio::cio::placement::LearnedPlacement;
use cio::cio::repair::{AvailabilityManager, RepairConfig};
use cio::cio::stage::CacheOutcome;
use cio::cio::transport::{ServerHandle, SocketTransport, Transport, TransportServer};
use cio::util::units::{kib, mib};
use std::io::BufRead;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn workspace(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("cio-serve-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Write a canonical single-member archive (member `"m"`) to GFS and
/// return its payload.
fn seed_archive(layout: &LocalLayout, name: &str, bytes: usize) -> Vec<u8> {
    let payload: Vec<u8> = (0..bytes).map(|j| (j % 251) as u8).collect();
    let mut w = Writer::create(&layout.gfs().join(name)).unwrap();
    w.add("m", &payload, Compression::None).unwrap();
    w.finish().unwrap();
    payload
}

/// Retries with no sleeps and an explicit per-source deadline.
fn wire_retry(deadline_ms: u64) -> RetryPolicy {
    RetryPolicy {
        attempts: 3,
        backoff_base_ms: 0,
        backoff_cap_ms: 0,
        jitter_seed: 11,
        source_deadline_ms: deadline_ms,
        quarantine_streak: 0,
        probation_fills: 1,
        hedge_delay_ms: 0,
    }
}

/// Move `cache` behind a serving loop on an ephemeral port; the handle's
/// address is what peers dial.
fn serve_cache(cache: GroupCache) -> ServerHandle {
    let source = Arc::new(ClusterRecordSource::new(Arc::new(vec![cache])));
    TransportServer::serve("127.0.0.1:0", source).unwrap()
}

/// Every counter that means "the GFS tier served bytes".
fn gfs_misses(cache: &GroupCache) -> u64 {
    let snap = cache.snapshot();
    snap.gfs_copies + snap.gfs_direct + snap.partial_gfs_reads + snap.degraded_reads
}

#[test]
fn socket_peer_serves_whole_archive_without_gfs() {
    let root = workspace("whole");
    let layout = LocalLayout::create(&root, 2, 1).unwrap(); // 2 groups
    let name = "s0-g0-00000.cioar";
    let payload = seed_archive(&layout, name, 60_000);
    let directory = Arc::new(RetentionDirectory::new(layout.ifs_groups()));
    let warm = GroupCache::with_directory(&layout, 0, mib(16), mib(16), directory.clone());
    warm.retain(&layout.gfs().join(name), name).unwrap();
    let server = serve_cache(warm);

    let reader = GroupCache::with_directory(&layout, 1, mib(16), mib(16), directory);
    reader.add_peer(0, Arc::new(SocketTransport::new(&server.addr().to_string(), 0)));
    // Kill the canonical copy: every byte — including the size probe the
    // resolve needs — must now come over the wire.
    std::fs::remove_file(layout.gfs().join(name)).unwrap();

    let (r, outcome) = reader.open_archive_via(&layout.gfs(), name, &[]).unwrap();
    assert_eq!(outcome, CacheOutcome::NeighborTransfer, "served from the peer's retention");
    assert_eq!(r.extract("m").unwrap(), payload, "byte-exact over the wire");
    let snap = reader.snapshot();
    assert_eq!(snap.neighbor_transfers, 1, "{snap:?}");
    assert_eq!(gfs_misses(&reader), 0, "GFS never touched: {snap:?}");
    assert!(server.served() >= 2, "probe + fetch crossed the wire");

    // Read-through: the fill retained the copy, so the next open hits.
    let (_, again) = reader.open_archive_via(&layout.gfs(), name, &[]).unwrap();
    assert_eq!(again, CacheOutcome::IfsHit);
}

#[test]
fn socket_peer_serves_record_ranges_without_gfs() {
    let root = workspace("range");
    let layout = LocalLayout::create(&root, 2, 1).unwrap();
    let name = "s0-g0-00000.cioar";
    let payload = seed_archive(&layout, name, 200_000);
    let directory = Arc::new(RetentionDirectory::new(layout.ifs_groups()));
    let warm = GroupCache::with_directory(&layout, 0, mib(16), mib(16), directory.clone());
    warm.retain(&layout.gfs().join(name), name).unwrap();
    let server = serve_cache(warm);

    let reader = GroupCache::with_directory(&layout, 1, mib(16), mib(16), directory)
        .with_fill_chunk(kib(16));
    reader.add_peer(0, Arc::new(SocketTransport::new(&server.addr().to_string(), 0)));
    std::fs::remove_file(layout.gfs().join(name)).unwrap();

    // A cold record-range read drives the extent engine: index extent
    // plus exactly the chunks covering the range, all over the wire.
    let (bytes, _) = reader
        .read_member_range_via(&layout.gfs(), name, &[], "m", 50_000, 10_000)
        .unwrap();
    assert_eq!(bytes, payload[50_000..60_000], "range is byte-exact over the wire");
    let snap = reader.snapshot();
    assert!(snap.chunk_fills >= 1, "the extent engine moved chunks: {snap:?}");
    assert!(snap.partial_neighbor_reads >= 1, "chunks came from the peer: {snap:?}");
    assert_eq!(gfs_misses(&reader), 0, "GFS never touched: {snap:?}");
    assert!(server.served() >= 2);
}

#[test]
fn cross_process_runner_serves_peer_retention() {
    let root = workspace("xproc");
    let layout = LocalLayout::create(&root, 2, 1).unwrap(); // 2 groups
    let name = "s0-g0-00000.cioar";
    let payload = seed_archive(&layout, name, 80_000);

    // Process A: a real second runner warming group 0's retention from
    // the shared GFS tree, then serving it over TCP. It persists the
    // retention manifest before printing READY, so this process can
    // bootstrap its routing directory from the shared filesystem.
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_cio-serve"))
        .arg(&root)
        .args(["2", "1", "0", name])
        .stdin(std::process::Stdio::piped())
        .stdout(std::process::Stdio::piped())
        .spawn()
        .expect("spawning cio-serve");
    let mut ready = String::new();
    std::io::BufReader::new(child.stdout.take().unwrap()).read_line(&mut ready).unwrap();
    let addr = ready
        .trim()
        .strip_prefix("READY ")
        .unwrap_or_else(|| panic!("unexpected cio-serve banner: {ready:?}"))
        .to_string();

    // Process B (this one): runner for group 1 on the same layout root.
    // Seed the directory from the peer's manifest and register the wire
    // route; the warm-routed read must never fall through to GFS.
    let directory = Arc::new(RetentionDirectory::new(layout.ifs_groups()));
    assert_eq!(bootstrap_peer_directory(&layout, &directory, 0), 1, "manifest entry published");
    let reader = GroupCache::with_directory(&layout, 1, mib(16), mib(16), directory);
    reader.add_peer(0, Arc::new(SocketTransport::new(&addr, 0)));

    let (r, outcome) = reader.open_archive_via(&layout.gfs(), name, &[]).unwrap();
    assert_eq!(outcome, CacheOutcome::NeighborTransfer, "warm-routed to the peer process");
    assert_eq!(r.extract("m").unwrap(), payload, "byte-exact across processes");
    let snap = reader.snapshot();
    assert_eq!(gfs_misses(&reader), 0, "gfs_misses == 0: {snap:?}");
    assert_eq!(snap.neighbor_transfers, 1, "{snap:?}");
    assert_eq!((snap.hits, snap.misses), (0, 1), "one cold resolve: {snap:?}");

    // Closing the child's stdin is its shutdown signal.
    drop(child.stdin.take());
    let status = child.wait().expect("cio-serve exits");
    assert!(status.success(), "cio-serve exited with {status:?}");
}

#[test]
fn mid_frame_drop_reroutes_to_gfs_byte_exact() {
    let root = workspace("torn");
    let layout = LocalLayout::create(&root, 2, 1).unwrap();
    let name = "s0-g0-00000.cioar";
    let payload = seed_archive(&layout, name, 70_000);
    let faults = Arc::new(FaultInjector::new());
    // Every serve of group 0's retained copy sends 1000 bytes of a
    // claimed-complete frame, then drops the connection.
    faults.inject(OpClass::Serve, "ifs/0/data", FaultAction::TruncateAfter(1000));
    let directory = Arc::new(RetentionDirectory::with_health(layout.ifs_groups(), 2, 4));
    let warm = GroupCache::with_directory(&layout, 0, mib(16), mib(16), directory.clone())
        .with_faults(faults.clone());
    warm.retain(&layout.gfs().join(name), name).unwrap();
    let server = serve_cache(warm);

    let reader = GroupCache::with_directory(&layout, 1, mib(16), mib(16), directory.clone())
        .with_retry(wire_retry(0));
    reader.add_peer(0, Arc::new(SocketTransport::new(&server.addr().to_string(), 0)));

    // The torn transfer is a transient wire fault: the fill re-routes to
    // the canonical GFS copy within the same resolve — no retry storm,
    // no wedged latch, and the peer's (healthy) retention entry stays
    // advertised.
    let (r, outcome) = reader.open_archive_via(&layout.gfs(), name, &[]).unwrap();
    assert_eq!(outcome, CacheOutcome::GfsMiss, "re-routed past the torn peer");
    assert_eq!(r.extract("m").unwrap(), payload, "byte-exact despite the torn frame");
    let snap = reader.snapshot();
    assert_eq!(snap.rerouted_fills, 1, "the failed probe was attributed: {snap:?}");
    assert_eq!(snap.stale_fallbacks, 0, "a torn wire is not staleness: {snap:?}");
    assert!(directory.sources(name).contains(&0), "the peer's entry stays advertised");
    assert!(faults.injected() >= 1, "the failpoint actually fired");
}

#[test]
fn stalled_peer_blows_deadline_reroutes_and_quarantines() {
    let root = workspace("stall");
    let layout = LocalLayout::create(&root, 2, 1).unwrap();
    let name = "s0-g0-00000.cioar";
    let payload = seed_archive(&layout, name, 40_000);
    let faults = Arc::new(FaultInjector::new());
    // Group 0's serving loop stalls every request well past the
    // reader's per-source deadline.
    faults.inject(OpClass::Serve, "ifs/0/data", FaultAction::Delay(Duration::from_millis(400)));
    // One blown probe trips the breaker (streak = 1).
    let directory = Arc::new(RetentionDirectory::with_health(layout.ifs_groups(), 1, 4));
    let warm = GroupCache::with_directory(&layout, 0, mib(16), mib(16), directory.clone())
        .with_faults(faults.clone());
    warm.retain(&layout.gfs().join(name), name).unwrap();
    let server = serve_cache(warm);

    let reader = GroupCache::with_directory(&layout, 1, mib(16), mib(16), directory.clone())
        .with_retry(wire_retry(60));
    reader.add_peer(
        0,
        Arc::new(SocketTransport::new(&server.addr().to_string(), 0)
            .with_timeouts(Duration::from_millis(500), Duration::from_millis(60))),
    );

    let (r, outcome) = reader.open_archive_via(&layout.gfs(), name, &[]).unwrap();
    assert_eq!(outcome, CacheOutcome::GfsMiss, "re-routed off the stalled peer");
    assert_eq!(r.extract("m").unwrap(), payload, "byte-exact after the stall");
    let snap = reader.snapshot();
    assert!(snap.deadline_aborts >= 1, "the stall was counted as a deadline abort: {snap:?}");
    assert_eq!(snap.rerouted_fills, 1, "{snap:?}");
    assert!(snap.quarantined_sources >= 1, "the breaker tripped: {snap:?}");
    assert!(directory.is_quarantined(0), "the stalled source is quarantined");
    assert!(directory.quarantine_trips() >= 1);
    drop(server);
}

#[test]
fn corrupt_wire_frame_reroutes_to_gfs_byte_exact() {
    let root = workspace("wire-corrupt");
    let layout = LocalLayout::create(&root, 2, 1).unwrap();
    let name = "s0-g0-00000.cioar";
    let payload = seed_archive(&layout, name, 70_000);
    let faults = Arc::new(FaultInjector::new());
    // Every frame served out of group 0's retention flips one payload
    // byte *after* the frame CRC is computed — in-flight wire damage.
    faults.inject(OpClass::Serve, "ifs/0/data", FaultAction::CorruptRange(500));
    let directory = Arc::new(RetentionDirectory::with_health(layout.ifs_groups(), 2, 4));
    let warm = GroupCache::with_directory(&layout, 0, mib(16), mib(16), directory.clone())
        .with_faults(faults.clone());
    warm.retain(&layout.gfs().join(name), name).unwrap();
    let server = serve_cache(warm);

    let reader = GroupCache::with_directory(&layout, 1, mib(16), mib(16), directory.clone())
        .with_retry(wire_retry(0));
    reader.add_peer(0, Arc::new(SocketTransport::new(&server.addr().to_string(), 0)));

    // The frame CRC catches the flip at arrival; the fill re-routes to
    // the canonical GFS copy within the same resolve, and the reader
    // never observes a wrong byte. The peer's retention itself is fine,
    // so its entry stays advertised.
    let (r, outcome) = reader.open_archive_via(&layout.gfs(), name, &[]).unwrap();
    assert_eq!(outcome, CacheOutcome::GfsMiss, "re-routed past the flipping wire");
    assert_eq!(r.extract("m").unwrap(), payload, "byte-exact despite the corrupt frame");
    let snap = reader.snapshot();
    assert_eq!(snap.rerouted_fills, 1, "{snap:?}");
    assert_eq!(snap.stale_fallbacks, 0, "wire damage is not staleness: {snap:?}");
    assert!(directory.sources(name).contains(&0), "the peer's entry stays advertised");
    assert!(faults.injected() >= 1, "the failpoint actually fired");
    drop(server);
}

#[test]
fn hard_killed_peer_reroutes_and_lease_expiry_withdraws_its_retention() {
    let root = workspace("kill");
    let layout = LocalLayout::create(&root, 2, 1).unwrap();
    let name = "s0-g0-00000.cioar";
    let name2 = "s0-g0-00001.cioar";
    let payload = seed_archive(&layout, name, 80_000);
    let payload2 = seed_archive(&layout, name2, 80_000);

    // Process A: a real runner warming both archives into group 0 and
    // serving them over TCP.
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_cio-serve"))
        .arg(&root)
        .args(["2", "1", "0", name, name2])
        .stdin(std::process::Stdio::piped())
        .stdout(std::process::Stdio::piped())
        .spawn()
        .expect("spawning cio-serve");
    let mut ready = String::new();
    std::io::BufReader::new(child.stdout.take().unwrap()).read_line(&mut ready).unwrap();
    let addr = ready
        .trim()
        .strip_prefix("READY ")
        .unwrap_or_else(|| panic!("unexpected cio-serve banner: {ready:?}"))
        .to_string();

    let directory = Arc::new(RetentionDirectory::new(layout.ifs_groups()));
    assert_eq!(bootstrap_peer_directory(&layout, &directory, 0), 2, "both entries published");
    let reader = GroupCache::with_directory(&layout, 1, mib(16), mib(16), directory.clone())
        .with_retry(wire_retry(2_000));
    let transport = Arc::new(
        SocketTransport::new(&addr, 0)
            .with_timeouts(Duration::from_millis(500), Duration::from_millis(500)),
    );
    reader.add_peer(0, transport.clone());

    // Warm resolve over the live peer: served, byte-exact, and the
    // connection is parked in the pool for reuse.
    let (r, outcome) = reader.open_archive_via(&layout.gfs(), name, &[]).unwrap();
    assert_eq!(outcome, CacheOutcome::NeighborTransfer, "served by the live peer");
    assert_eq!(r.extract("m").unwrap(), payload);
    transport.ping().expect("a live peer answers the heartbeat");

    // The lifecycle monitor heartbeats the peer and keeps its lease
    // current; ttl > 3 sweeps, so only sustained silence expires it.
    let monitor = PeerMonitor::start(
        directory.clone(),
        vec![(0, transport.clone() as Arc<dyn Transport>)],
        Duration::from_millis(40),
        Duration::from_millis(150),
    );

    // Hard-kill the serving process — no shutdown handshake, the pooled
    // connection dies with it.
    child.kill().expect("killing cio-serve");
    child.wait().expect("reaping cio-serve");

    // The next fetch rides the stale pooled connection: the transport
    // must detect the dead stream, attempt a replacement, and fail the
    // probe cleanly; the fill re-routes to GFS byte-exact with no
    // wedged latch.
    let (r2, out2) = reader.open_archive_via(&layout.gfs(), name2, &[]).unwrap();
    assert_eq!(out2, CacheOutcome::GfsMiss, "re-routed off the dead peer");
    assert_eq!(r2.extract("m").unwrap(), payload2, "byte-exact after the kill");
    assert!(reader.snapshot().rerouted_fills >= 1, "{:?}", reader.snapshot());
    assert!(
        transport.reconnects() >= 1,
        "the stale pooled connection was detected and replaced (reconnects = {})",
        transport.reconnects()
    );

    // Within roughly one lease of the kill, the missed heartbeats expire
    // the lease and withdraw the dead peer's *entire* advertised
    // retention in one step.
    let deadline = Instant::now() + Duration::from_secs(10);
    while directory.lease_expirations() == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(directory.lease_expirations() >= 1, "the dead peer's lease must expire");
    assert!(!directory.sources(name).contains(&0), "entry withdrawn with the lease");
    assert!(!directory.sources(name2).contains(&0), "all entries withdrawn in one step");
    assert!(directory.expired_peers().contains(&0));
    drop(monitor);

    // Routing now skips the dead peer entirely: a fresh producer-owned
    // archive resolves straight from GFS without probing it (the
    // expired lease gates even the producer fallback).
    assert!(!directory.probe_allowed(0), "an expired peer is not probe-eligible");
    let name3 = "s0-g0-00002.cioar";
    let payload3 = seed_archive(&layout, name3, 30_000);
    let reconnects_before = transport.reconnects();
    let (r3, out3) = reader.open_archive_via(&layout.gfs(), name3, &[]).unwrap();
    assert_eq!(out3, CacheOutcome::GfsMiss, "no route through the dead peer");
    assert_eq!(r3.extract("m").unwrap(), payload3);
    assert_eq!(
        transport.reconnects(),
        reconnects_before,
        "the dead peer was never dialed again"
    );
}

#[test]
fn saturated_server_sheds_busy_and_clients_retry_through() {
    let root = workspace("busy");
    let layout = LocalLayout::create(&root, 2, 1).unwrap();
    let name = "s0-g0-00000.cioar";
    let payload = seed_archive(&layout, name, 60_000);
    let faults = Arc::new(FaultInjector::new());
    // Every serve holds its connection long enough that concurrent
    // clients genuinely overlap — the cap must actually bind.
    faults.inject(OpClass::Serve, "ifs/0/data", FaultAction::Delay(Duration::from_millis(50)));
    let warm = GroupCache::new(&layout, 0, mib(16)).with_faults(faults);
    warm.retain(&layout.gfs().join(name), name).unwrap();
    let source = Arc::new(ClusterRecordSource::new(Arc::new(vec![warm])));
    // One live connection at a time: everyone else gets a BUSY frame.
    let server = TransportServer::serve_capped("127.0.0.1:0", source, 1).unwrap();
    let addr = server.addr().to_string();

    let threads = 6;
    let barrier = std::sync::Barrier::new(threads);
    let busy_errors: u64 = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|i| {
                let addr = &addr;
                let root = &root;
                let barrier = &barrier;
                scope.spawn(move || {
                    let t = SocketTransport::new(addr, 0);
                    let dst = root.join(format!("busy-fetch-{i}.cioar"));
                    barrier.wait();
                    let deadline = Instant::now() + Duration::from_secs(30);
                    let mut busy = 0u64;
                    loop {
                        match t.fetch_archive(name, &dst, Some(Duration::from_secs(10))) {
                            Ok(_) => break,
                            Err(e) => {
                                assert!(
                                    e.retryable,
                                    "saturation must surface as a retryable error: {e:?}"
                                );
                                busy += 1;
                                assert!(
                                    Instant::now() < deadline,
                                    "a saturated server must shed load, not wedge \
                                     ({busy} rejections and counting)"
                                );
                                std::thread::sleep(Duration::from_millis(5));
                            }
                        }
                    }
                    // Free the live-connection slot before verifying, so
                    // the remaining clients drain promptly.
                    drop(t);
                    let r = Reader::open(&dst).unwrap();
                    (busy, r.extract("m").unwrap())
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                let (busy, bytes) = h.join().unwrap();
                assert_eq!(bytes, payload, "every client drains through byte-exact");
                busy
            })
            .sum()
    });
    // With six clients racing one slot behind a 50 ms serve, some of
    // them were necessarily turned away — and the server counted it.
    assert!(busy_errors >= 1, "at least one client saw the typed retryable rejection");
    assert!(
        server.busy_rejections() >= 1,
        "the cap actually bound: {} rejections",
        server.busy_rejections()
    );
}

#[test]
fn killed_peer_lease_expiry_feeds_repair_until_reads_skip_gfs() {
    let root = workspace("heal");
    let layout = LocalLayout::create(&root, 4, 1).unwrap(); // groups 0..3
    let names = ["s0-g0-00000.cioar", "s0-g0-00001.cioar", "s0-g0-00002.cioar"];
    let payloads: Vec<Vec<u8>> =
        names.iter().map(|n| seed_archive(&layout, n, 40_000)).collect();

    // Process A: the *sole* live source of all three archives.
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_cio-serve"))
        .arg(&root)
        .args(["4", "1", "0"])
        .args(names)
        .stdin(std::process::Stdio::piped())
        .stdout(std::process::Stdio::piped())
        .spawn()
        .expect("spawning cio-serve");
    let mut ready = String::new();
    std::io::BufReader::new(child.stdout.take().unwrap()).read_line(&mut ready).unwrap();
    let addr = ready
        .trim()
        .strip_prefix("READY ")
        .unwrap_or_else(|| panic!("unexpected cio-serve banner: {ready:?}"))
        .to_string();

    let directory = Arc::new(RetentionDirectory::new(layout.ifs_groups()));
    assert_eq!(bootstrap_peer_directory(&layout, &directory, 0), 3, "peer advertises all 3");
    for n in &names {
        assert_eq!(directory.sources(n), vec![0], "the peer is the sole live source");
    }

    // The availability manager attaches *before* the failure so the
    // lease expiry's replica-loss events land in its log; every archive
    // is known-popular (read counts above the threshold), so each wants
    // two live replicas.
    let cfg = RepairConfig {
        replica_target: 2,
        popularity_threshold: 0,
        byte_budget_per_tick: 100_000,
        max_inflight_per_tick: 2,
        tick_ms: 5,
        scrub_period_ms: 60_000,
        scrub_batch: 4,
    };
    let mgr = AvailabilityManager::new(directory.clone(), cfg);
    let mut learned = LearnedPlacement::new();
    for n in &names {
        learned.record_reads(n, 41_000, 5);
    }
    mgr.seed_popularity(&learned);

    // Heartbeats keep the lease current while the peer lives...
    let transport = Arc::new(SocketTransport::new(&addr, 0));
    transport.ping().expect("a live peer answers the heartbeat");
    let monitor = PeerMonitor::start(
        directory.clone(),
        vec![(0, transport.clone() as Arc<dyn Transport>)],
        Duration::from_millis(40),
        Duration::from_millis(150),
    );

    // ...then the hard kill: no handshake, just sustained silence.
    child.kill().expect("killing cio-serve");
    child.wait().expect("reaping cio-serve");
    let deadline = Instant::now() + Duration::from_secs(10);
    while directory.lease_expirations() == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(directory.lease_expirations() >= 1, "the dead peer's lease must expire");
    for n in &names {
        assert!(directory.sources(n).is_empty(), "{n}: every replica died with the peer");
    }
    drop(monitor);

    // Control arm (repair disabled): a runner reading now pays the GFS
    // tier for every archive — the tiny capacity forces direct central
    // reads with no retention side effects, and the private directory
    // keeps the control run out of the healing arm's routing state.
    let control = GroupCache::with_directory(
        &layout,
        1,
        64,
        64,
        Arc::new(RetentionDirectory::new(layout.ifs_groups())),
    );
    for (n, p) in names.iter().zip(&payloads) {
        let (r, outcome) = control.open_archive_via(&layout.gfs(), n, &[]).unwrap();
        assert_eq!(outcome, CacheOutcome::GfsMiss, "no repair -> central store");
        assert_eq!(&r.extract("m").unwrap(), p);
    }
    assert!(gfs_misses(&control) >= 3, "one GFS round trip per archive without repair");

    // Healing arm: groups 1 and 2 host the re-replicated copies. Tick
    // the manager the way the daemon does, asserting the per-tick byte
    // budget is a hard cap, until every archive is back at target.
    let caches = Arc::new(vec![
        GroupCache::with_directory(&layout, 1, mib(16), mib(16), directory.clone()),
        GroupCache::with_directory(&layout, 2, mib(16), mib(16), directory.clone()),
    ]);
    let exec = RunnerRepairExecutor::new(caches.clone(), layout.gfs());
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let out = mgr.tick(&exec);
        assert!(
            out.bytes <= cfg.byte_budget_per_tick,
            "the byte budget is a hard per-tick cap: {out:?}"
        );
        if names.iter().all(|n| directory.sources(n).len() >= 2) {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "repair must converge (queue {} deep, pushes {})",
            mgr.queue_len(),
            mgr.repair_pushes()
        );
    }
    assert_eq!(mgr.repair_pushes(), 6, "two replicas per archive, no spurious pushes");
    assert_eq!(mgr.orphan_repairs(), 3, "the first push of each archive revived an orphan");
    assert_eq!(mgr.repair_failures(), 0, "{:?}", cfg);

    // Third runner (group 3, cold cache, shared routing): every read is
    // now served by the repaired replicas — the central store is out of
    // the steady state again, the §5.3 claim this PR defends.
    let reader = GroupCache::with_directory(&layout, 3, mib(16), mib(16), directory.clone());
    for (n, p) in names.iter().zip(&payloads) {
        let (r, outcome) = reader.open_archive_via(&layout.gfs(), n, &caches).unwrap();
        assert_eq!(outcome, CacheOutcome::NeighborTransfer, "{n}: served by a repaired copy");
        assert_eq!(&r.extract("m").unwrap(), p, "{n}: byte-exact after healing");
    }
    assert_eq!(gfs_misses(&reader), 0, "repair pre-positioned every read: {:?}", reader.snapshot());
}
