//! Figure 17 (+ §6.3 text): the DOCK6 molecular-docking workflow.
//!
//! Paper anchors, 15K tasks on 8K processors:
//!   total 2140 s (GPFS) vs 1412 s (CIO);
//!   stage 1 ≈ 1.06×, stage 2 = 11.7× (694 s → 59 s), stage 3 ≈ 1.5×.
//! Large run (pass `-- --large`), 135K tasks on 96K processors, stage 1
//! only: 1981 s (GPFS) vs 1772 s (CIO) = 1.12× — compute-bound, as the
//! paper expects.
//!
//! Regenerate: `cargo bench --bench fig17` (add `-- --large` for §6.3's
//! 96K-processor run).

#[path = "common/mod.rs"]
mod common;

use cio::config::ClusterConfig;
use cio::sim::cluster::IoMode;
use cio::workload::dock::{run_comparison, DockWorkflow};

fn main() {
    let args = common::args();
    let cfg = ClusterConfig::bgp(8192);
    let report = run_comparison(&cfg, 15_360).expect("dock comparison");
    common::footer(&report);

    if args.has("large") && !common::fast() {
        println!("--- §6.3 large run: 135K tasks on 96K processors (stage 1 only) ---");
        let cfg = ClusterConfig::bgp(98_304);
        let wf = DockWorkflow { tasks: 135_168, ..Default::default() };
        let gpfs = wf.run(&cfg, IoMode::Gpfs);
        let cio = wf.run(&cfg, IoMode::Cio);
        let mut large = cio::metrics::Report::new("§6.3 large run (stage 1)");
        large.push("GPFS stage1", 1981.0, gpfs.stage1_s, "s");
        large.push("CIO stage1", 1772.0, cio.stage1_s, "s");
        large.push("speedup", 1.12, gpfs.stage1_s / cio.stage1_s, "x");
        common::footer(&large);
    }
}
