//! Descriptive statistics for bench reporting and metric aggregation.

/// Summary statistics over a sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Number of observations.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation (n-1 denominator; 0 for n < 2).
    pub stddev: f64,
    /// Minimum.
    pub min: f64,
    /// Maximum.
    pub max: f64,
    /// Median (p50).
    pub p50: f64,
    /// 95th percentile.
    pub p95: f64,
    /// 99th percentile.
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; returns `None` on an empty sample.
    pub fn of(xs: &[f64]) -> Option<Summary> {
        if xs.is_empty() {
            return None;
        }
        let mut sorted: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
        if sorted.is_empty() {
            return None;
        }
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            sorted.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Some(Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p95: percentile_sorted(&sorted, 0.95),
            p99: percentile_sorted(&sorted, 0.99),
        })
    }
}

/// Linear-interpolated percentile of a pre-sorted sample, `q` in `[0,1]`.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Online mean/variance accumulator (Welford). Used by the simulator's
/// metric counters where storing every observation would be wasteful.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    /// Fresh accumulator.
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Fold in one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Count of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        if self.n < 2 { 0.0 } else { (self.m2 / (self.n - 1) as f64).sqrt() }
    }

    /// Minimum (NaN if empty).
    pub fn min(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.min }
    }

    /// Maximum (NaN if empty).
    pub fn max(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.max }
    }

    /// Sum of all observations.
    pub fn sum(&self) -> f64 {
        self.mean() * self.n as f64
    }
}

/// Fixed-bucket histogram on a log2 scale; used for latency distributions
/// in the collector and dispatcher metrics.
#[derive(Debug, Clone)]
pub struct Log2Histogram {
    buckets: Vec<u64>,
    /// Value (in the same unit as `push`) of the first bucket's upper edge.
    base: f64,
}

impl Log2Histogram {
    /// `base` is the upper edge of bucket 0; bucket i covers
    /// `(base * 2^(i-1), base * 2^i]`.
    pub fn new(base: f64, nbuckets: usize) -> Self {
        assert!(base > 0.0 && nbuckets > 0);
        Self { buckets: vec![0; nbuckets], base }
    }

    /// Record one value.
    pub fn push(&mut self, x: f64) {
        let idx = if x <= self.base {
            0
        } else {
            ((x / self.base).log2().ceil() as usize).min(self.buckets.len() - 1)
        };
        self.buckets[idx] += 1;
    }

    /// Bucket counts.
    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }

    /// Total observations.
    pub fn total(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Approximate quantile from the histogram (upper edge of the bucket
    /// containing the q-th observation).
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.total();
        if total == 0 {
            return f64::NAN;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.base * 2f64.powi(i as i32);
            }
        }
        self.base * 2f64.powi(self.buckets.len() as i32 - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn summary_empty_and_nan() {
        assert!(Summary::of(&[]).is_none());
        assert!(Summary::of(&[f64::NAN]).is_none());
        // NaNs filtered, finite values kept.
        let s = Summary::of(&[f64::NAN, 2.0]).unwrap();
        assert_eq!(s.n, 1);
        assert_eq!(s.mean, 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 10.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 40.0);
        assert!((percentile_sorted(&xs, 0.5) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64 * 0.7).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let s = Summary::of(&xs).unwrap();
        assert!((w.mean() - s.mean).abs() < 1e-9);
        assert!((w.stddev() - s.stddev).abs() < 1e-9);
        assert_eq!(w.min(), s.min);
        assert_eq!(w.max(), s.max);
        assert_eq!(w.count(), 100);
    }

    #[test]
    fn histogram_buckets_and_quantile() {
        let mut h = Log2Histogram::new(1.0, 10);
        for x in [0.5, 1.0, 2.0, 3.0, 4.0, 100.0] {
            h.push(x);
        }
        assert_eq!(h.total(), 6);
        assert_eq!(h.buckets()[0], 2); // 0.5, 1.0
        assert_eq!(h.buckets()[1], 1); // 2.0
        assert_eq!(h.buckets()[2], 2); // 3.0, 4.0
        assert_eq!(h.buckets()[7], 1); // 100 -> 128
        assert!(h.quantile(0.5) <= 4.0);
        assert!(h.quantile(1.0) >= 100.0);
    }
}
