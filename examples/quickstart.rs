//! Quickstart: simulate a loosely coupled MTC workload on a BG/P-style
//! partition and compare collective IO against direct GPFS writes.
//!
//! Run: `cargo run --release --example quickstart`

use cio::config::ClusterConfig;
use cio::sim::cluster::IoMode;
use cio::util::table::Table;
use cio::util::units::{fmt_bw, mib};
use cio::workload::synthetic::SyntheticWorkload;

fn main() {
    // A 4096-processor partition with the Argonne defaults: 64 compute
    // nodes per ION, RAM-based LFS, GPFS-like GFS.
    let cfg = ClusterConfig::bgp(4096);
    // Three waves of 4-second tasks, each writing a 1 MiB output file —
    // the paper's Figure 14 shape.
    let wl = SyntheticWorkload::waves(&cfg, 3, 4.0, mib(1));

    let ideal = wl.run(&cfg, IoMode::RamOnly);
    let gpfs = wl.run(&cfg, IoMode::Gpfs);
    let cio = wl.run(&cfg, IoMode::Cio);

    let mut t = Table::new(vec!["metric", "GPFS", "CIO", "ideal (RAM)"])
        .title(format!("{} tasks x 4s x 1MiB on {} processors", wl.tasks, cfg.procs));
    t.row(vec![
        "efficiency".to_string(),
        format!("{:.1}%", gpfs.efficiency_vs(&ideal) * 100.0),
        format!("{:.1}%", cio.efficiency_vs(&ideal) * 100.0),
        "100%".to_string(),
    ]);
    t.row(vec![
        "write throughput".to_string(),
        fmt_bw(gpfs.write_throughput(mib(1))),
        fmt_bw(cio.write_throughput(mib(1))),
        fmt_bw(ideal.write_throughput(mib(1))),
    ]);
    t.row(vec![
        "GFS files created".to_string(),
        format!("{}", gpfs.gfs_files),
        format!("{}", cio.gfs_files),
        "0".to_string(),
    ]);
    t.row(vec![
        "file-count reduction".to_string(),
        "1x".to_string(),
        format!("{:.0}x", cio.collector.reduction_factor()),
        "-".to_string(),
    ]);
    print!("{}", t.render());
    println!("Collector flush reasons [maxDelay, maxData, minFree, shutdown]: {:?}", cio.collector.reasons);
    println!("\nNext: `cargo bench --bench fig14` regenerates the full figure;");
    println!("      `cargo run --release --example dock_screening` runs the real-compute pipeline.");
}
