//! §Perf probe: the three 96K-processor × 295K-task simulations used as
//! the whole-stack optimization workload (EXPERIMENTS.md §Perf). Prints
//! build/run wall time, event counts and event rate per IO mode.
//!
//! Run: `cargo run --release --example perf_probe`

use cio::config::ClusterConfig;
use cio::sim::cluster::{IoMode, SimCluster};
use cio::util::units::mib;
use std::time::Instant;

fn main() {
    for (procs, mode) in [(98_304u32, IoMode::Cio), (98_304, IoMode::Gpfs), (98_304, IoMode::RamOnly)] {
        let cfg = ClusterConfig::bgp(procs);
        let tasks = procs as u64 * 3;
        let t0 = Instant::now();
        let mut c = SimCluster::new(&cfg);
        let built = t0.elapsed();
        let t1 = Instant::now();
        let r = c.run_mtc(tasks, 32.0, mib(1), mode);
        let ran = t1.elapsed();
        println!(
            "{procs} procs {:?}: build {:.3}s run {:.3}s, {} events, {:.2} Mev/s, tasks {}",
            mode, built.as_secs_f64(), ran.as_secs_f64(),
            c.engine.processed(), c.engine.processed() as f64 / ran.as_secs_f64() / 1e6, r.tasks
        );
    }
}
