//! Criterion-like micro/macro benchmark harness (no `criterion` offline).
//!
//! The `rust/benches/*.rs` targets are `harness = false` binaries that use
//! this module. Two kinds of measurement coexist:
//!
//! * **wall-clock benches** ([`Bencher::iter`]) for real hot paths (archive
//!   writer, event queue, PJRT execute) — warmup, fixed-duration sampling,
//!   mean/p50/p95 in ns/iter;
//! * **figure benches** (the `figNN` targets) which *run the simulator* and
//!   print paper-vs-measured tables; those use [`crate::util::table`]
//!   directly and only use [`Bencher`] for their own runtime accounting.

use crate::util::stats::Summary;
use std::time::{Duration, Instant};

/// Configuration for a wall-clock measurement.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Warmup duration before sampling.
    pub warmup: Duration,
    /// Target sampling duration.
    pub measure: Duration,
    /// Maximum number of samples (batches).
    pub max_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(200),
            measure: Duration::from_secs(1),
            max_samples: 200,
        }
    }
}

impl BenchConfig {
    /// Quick config for CI / smoke runs (`CIO_BENCH_FAST=1`).
    pub fn fast() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(20),
            measure: Duration::from_millis(100),
            max_samples: 30,
        }
    }

    /// Pick the default or the fast profile from the environment.
    pub fn from_env() -> Self {
        if std::env::var_os("CIO_BENCH_FAST").is_some() {
            BenchConfig::fast()
        } else {
            BenchConfig::default()
        }
    }
}

/// One benchmark's result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark id.
    pub name: String,
    /// Per-iteration statistics, nanoseconds.
    pub ns_per_iter: Summary,
    /// Total iterations executed while sampling.
    pub iters: u64,
}

impl BenchResult {
    /// Throughput in iterations/second based on the mean.
    pub fn iters_per_sec(&self) -> f64 {
        1e9 / self.ns_per_iter.mean
    }

    /// Render a one-line report, criterion-style.
    pub fn line(&self) -> String {
        format!(
            "{:<40} {:>12.1} ns/iter (p50 {:>10.1}, p95 {:>10.1}, n={})",
            self.name, self.ns_per_iter.mean, self.ns_per_iter.p50, self.ns_per_iter.p95, self.ns_per_iter.n
        )
    }

    /// One-line machine-readable JSON record.
    pub fn json(&self) -> String {
        format!(
            "{{\"kind\":\"bench\",\"name\":{},\"ns_per_iter_mean\":{:.1},\"ns_p50\":{:.1},\"ns_p95\":{:.1},\"iters\":{}}}",
            json_str(&self.name),
            self.ns_per_iter.mean,
            self.ns_per_iter.p50,
            self.ns_per_iter.p95,
            self.iters
        )
    }
}

/// A named scalar recorded alongside bench results (throughputs,
/// latencies derived outside [`Bencher::iter`]'s ns/iter framing).
#[derive(Debug, Clone)]
pub struct Metric {
    /// Metric id.
    pub name: String,
    /// Value in `unit`.
    pub value: f64,
    /// Unit label (e.g. `"MiB/s"`, `"us"`).
    pub unit: String,
}

impl Metric {
    /// One-line machine-readable JSON record.
    pub fn json(&self) -> String {
        format!(
            "{{\"kind\":\"metric\",\"name\":{},\"value\":{:.3},\"unit\":{}}}",
            json_str(&self.name),
            self.value,
            json_str(&self.unit)
        )
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// The harness: collects results, prints a summary.
#[derive(Debug, Default)]
pub struct Bencher {
    config: BenchConfig,
    results: Vec<BenchResult>,
    metrics: Vec<Metric>,
}

impl Bencher {
    /// Harness with config from the environment.
    pub fn new() -> Self {
        Bencher { config: BenchConfig::from_env(), results: Vec::new(), metrics: Vec::new() }
    }

    /// Harness with an explicit config.
    pub fn with_config(config: BenchConfig) -> Self {
        Bencher { config, results: Vec::new(), metrics: Vec::new() }
    }

    /// Measure `f`, batching iterations adaptively so that timer overhead
    /// is amortized for nanosecond-scale bodies. Returns the result and
    /// records it for [`Bencher::report`].
    pub fn iter<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Estimate cost with a single call, choose batch size ~100us.
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().max(Duration::from_nanos(1));
        let batch = ((Duration::from_micros(100).as_nanos() / once.as_nanos()).max(1)) as u64;

        // Warmup.
        let warm_until = Instant::now() + self.config.warmup;
        while Instant::now() < warm_until {
            for _ in 0..batch {
                f();
            }
        }

        // Sample.
        let mut samples = Vec::new();
        let mut iters = 0u64;
        let sample_until = Instant::now() + self.config.measure;
        while Instant::now() < sample_until && samples.len() < self.config.max_samples {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            let dt = t.elapsed();
            samples.push(dt.as_nanos() as f64 / batch as f64);
            iters += batch;
        }
        let result = BenchResult {
            name: name.to_string(),
            ns_per_iter: Summary::of(&samples).expect("at least one sample"),
            iters,
        };
        println!("{}", result.line());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Time a single long-running body (figure sims): one warmless sample.
    pub fn once<T, F: FnOnce() -> T>(&mut self, name: &str, f: F) -> T {
        let t0 = Instant::now();
        let out = f();
        let dt = t0.elapsed();
        let result = BenchResult {
            name: name.to_string(),
            ns_per_iter: Summary::of(&[dt.as_nanos() as f64]).unwrap(),
            iters: 1,
        };
        println!("{:<40} {:>10.3} s (single run)", name, dt.as_secs_f64());
        self.results.push(result);
        out
    }

    /// Record a derived scalar (throughput, latency percentile, …) so it
    /// lands in the JSON output next to the ns/iter results.
    pub fn metric(&mut self, name: &str, value: f64, unit: &str) {
        println!("{:<40} {:>12.3} {}", name, value, unit);
        self.metrics.push(Metric {
            name: name.to_string(),
            value,
            unit: unit.to_string(),
        });
    }

    /// All recorded results.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// All recorded metrics.
    pub fn metrics(&self) -> &[Metric] {
        &self.metrics
    }

    /// Machine-readable output: one JSON object per line (benches then
    /// metrics) — the format `BENCH_PR*.json` baselines are stored in.
    pub fn to_json_lines(&self) -> String {
        let mut out = String::new();
        for r in &self.results {
            out.push_str(&r.json());
            out.push('\n');
        }
        for m in &self.metrics {
            out.push_str(&m.json());
            out.push('\n');
        }
        out
    }

    /// Write [`Bencher::to_json_lines`] to `path`.
    pub fn write_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json_lines())
    }

    /// Print the final summary block.
    pub fn report(&self) {
        println!("\n--- bench summary ({} benchmarks) ---", self.results.len());
        for r in &self.results {
            println!("{}", r.line());
        }
        for m in &self.metrics {
            println!("{:<40} {:>12.3} {}", m.name, m.value, m.unit);
        }
    }
}

/// Prevent the optimizer from eliding a value (stable-rust black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_cheap_body() {
        let mut b = Bencher::with_config(BenchConfig {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(20),
            max_samples: 10,
        });
        let mut acc = 0u64;
        let r = b.iter("noop-add", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.ns_per_iter.mean > 0.0);
        assert!(r.iters > 0);
        assert!(r.iters_per_sec() > 1000.0);
    }

    #[test]
    fn once_returns_value() {
        let mut b = Bencher::with_config(BenchConfig::fast());
        let v = b.once("compute", || 21 * 2);
        assert_eq!(v, 42);
        assert_eq!(b.results().len(), 1);
        assert_eq!(b.results()[0].iters, 1);
    }

    #[test]
    fn fast_profile_from_env_flag() {
        let cfg = BenchConfig::fast();
        assert!(cfg.measure < BenchConfig::default().measure);
    }

    #[test]
    fn json_lines_cover_results_and_metrics() {
        let mut b = Bencher::with_config(BenchConfig::fast());
        b.once("unit \"quoted\"", || 1);
        b.metric("archive: write MiB/s", 123.456, "MiB/s");
        let json = b.to_json_lines();
        let lines: Vec<&str> = json.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("{\"kind\":\"bench\""), "{}", lines[0]);
        assert!(lines[0].contains("\\\"quoted\\\""), "escaping: {}", lines[0]);
        assert!(lines[1].contains("\"value\":123.456"), "{}", lines[1]);
        assert!(lines[1].contains("\"unit\":\"MiB/s\""), "{}", lines[1]);
    }

    #[test]
    fn json_escapes_control_chars() {
        assert_eq!(json_str("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(json_str("\u{1}"), "\"\\u0001\"");
    }
}
