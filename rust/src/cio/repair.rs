//! Self-healing retention (PR 10): an availability manager that puts
//! replicas *back* when the serving tier loses them.
//!
//! The paper's broadcast insight (§5.1) is that popular data should
//! already be resident where readers will want it. Every earlier layer
//! only *loses* replicas over time — lease expiry withdraws a dead
//! peer's whole advertisement, scrub drops rotted copies, eviction
//! claims the last copy of an archive nobody read recently — and readers
//! then fall back to GFS until demand re-pulls the bytes: exactly the
//! shared-filesystem burden the paper eliminates. The
//! [`AvailabilityManager`] closes that loop.
//!
//! # Event sources
//!
//! The manager feeds one prioritized repair queue from three places:
//!
//! 1. **Peer-lease expirations.** [`PeerMonitor`] sweeps
//!    [`RetentionDirectory::expire_overdue`]; archives whose *only* live
//!    source died are logged as [`OrphanCause::PeerExpiry`] and queued
//!    with top urgency — until repaired, every read of them is a GFS
//!    round trip.
//! 2. **Scrub drops.** A scrub pass that finds a rotted copy and cannot
//!    re-fetch it drops the replica through
//!    [`RetentionDirectory::record_scrub_drop`]; the
//!    [`OrphanCause::ScrubDrop`] event triggers a deficit re-audit even
//!    while other replicas survive.
//! 3. **Last-replica eviction.** A directory withdrawal that empties an
//!    archive's source set logs [`OrphanCause::Eviction`]. Cold archives
//!    are *not* re-replicated on eviction (that would undo the LRU's
//!    capacity management); only archives whose observed read count
//!    clears the popularity threshold are.
//!
//! # Replica targets
//!
//! Targets derive from [`LearnedPlacement`] read counts — the §7
//! "learn from the IO patterns of previous runs" signal finally gets a
//! consumer: archives read by more than
//! [`RepairConfig::popularity_threshold`] distinct tasks want
//! [`RepairConfig::replica_target`] live sources; everything else wants
//! one. [`AvailabilityManager::audit_deficits`] additionally walks every
//! observed-popular archive each tick, so a deficit that never produced
//! an orphan event (e.g. a replica lost before the manager attached) is
//! still found.
//!
//! # Rate limits
//!
//! Repair must never starve foreground fills. Each
//! [`AvailabilityManager::tick`]:
//!
//! * is **idle-triggered** — when [`RepairExecutor::foreground_busy`]
//!   reports in-flight foreground fills the tick only absorbs events and
//!   defers all movement;
//! * launches at most [`RepairConfig::max_inflight_per_tick`] pushes;
//! * moves at most [`RepairConfig::byte_budget_per_tick`] bytes — a hard
//!   cap, checked *before* each push. An archive larger than the whole
//!   per-tick budget is dropped as unrepairable (counted in
//!   `repair_failures`), mirroring the neighbor-transfer size cap on the
//!   foreground path.
//!
//! Failed pushes are retried with fresh routing up to three attempts,
//! then dropped (and counted) — a persistently failing repair must not
//! wedge the queue.
//!
//! # Scrub cadence
//!
//! The same [`MaintenanceDaemon`] thread owns scrub scheduling: every
//! [`RepairConfig::scrub_period_ms`] it runs one
//! [`RepairExecutor::scrub_slice`] of at most
//! [`RepairConfig::scrub_batch`] archives, least-recently-verified
//! first. Per-archive last-verified times persist in the retention
//! manifest (`#scrubbed` lines), so a restarted runner resumes the cycle
//! where it left off instead of re-verifying everything.
//!
//! # Shutdown semantics
//!
//! The daemon is owned by the [`StageRunner`] and stopped *before* the
//! runner saves manifests: [`MaintenanceDaemon::stop`] sets the stop
//! flag, lets the in-flight tick finish, runs one final non-idle-gated
//! drain tick (so an event absorbed moments before shutdown still gets
//! its bounded budget of repair), and joins the thread. Dropping the
//! daemon stops it.
//!
//! [`PeerMonitor`]: crate::cio::local_stage::PeerMonitor
//! [`StageRunner`]: crate::cio::local_stage::StageRunner
//! [`RetentionDirectory::expire_overdue`]: crate::cio::directory::RetentionDirectory::expire_overdue
//! [`RetentionDirectory::record_scrub_drop`]: crate::cio::directory::RetentionDirectory::record_scrub_drop

use crate::cio::directory::{OrphanCause, RetentionDirectory};
use crate::cio::placement::LearnedPlacement;
use anyhow::Result;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Give up on a repair after this many failed pushes (each with fresh
/// routing): a rotted GFS copy or a cluster with no accepting target
/// must not wedge the queue.
const MAX_ATTEMPTS: u32 = 3;

/// Self-healing knobs, usually derived from placement scale by
/// [`crate::cio::placement::PlacementPolicy::repair_config`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RepairConfig {
    /// Live sources a *popular* archive wants; everything else wants 1.
    pub replica_target: u32,
    /// Observed read count strictly above this marks an archive popular
    /// (the §5.1 read-many line).
    pub popularity_threshold: u32,
    /// Hard cap on bytes moved per maintenance tick.
    pub byte_budget_per_tick: u64,
    /// Maximum repair pushes launched per tick.
    pub max_inflight_per_tick: usize,
    /// Maintenance tick period in milliseconds.
    pub tick_ms: u64,
    /// Scrub-slice period in milliseconds.
    pub scrub_period_ms: u64,
    /// Archives verified per scrub slice, least-recently-verified first.
    pub scrub_batch: usize,
}

impl RepairConfig {
    /// The tick period as a [`Duration`].
    pub fn tick(&self) -> Duration {
        Duration::from_millis(self.tick_ms)
    }

    /// The scrub period as a [`Duration`].
    pub fn scrub_period(&self) -> Duration {
        Duration::from_millis(self.scrub_period_ms)
    }
}

/// What one [`AvailabilityManager::tick`] did — returned so callers
/// (daemon, benches, tests) can observe progress without re-deriving it
/// from counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TickOutcome {
    /// Replicas pushed this tick.
    pub pushes: u64,
    /// Bytes moved this tick (always ≤ the configured budget).
    pub bytes: u64,
    /// True when foreground fills deferred all movement.
    pub deferred_busy: bool,
}

/// The cluster-side muscle the manager directs. Implemented over a
/// runner's group caches in `local_stage` (replicate = the existing
/// verified routed-fill/`Transport::publish` path, so repaired copies
/// are checksum-verified, directory-published, and evictable), and by
/// in-memory mocks in tests.
pub trait RepairExecutor: Send + Sync {
    /// Candidate target groups for a new replica of `archive`, best
    /// first (the executor owns topology: torus distance to the existing
    /// sources/producer, capacity, acceptance). Groups already listed as
    /// sources must be excluded.
    fn candidate_groups(&self, archive: &str) -> Vec<u32>;

    /// Size of `archive` in bytes, or `None` when no copy (retained or
    /// GFS) can be found to measure — such an archive is unrepairable.
    fn archive_bytes(&self, archive: &str) -> Option<u64>;

    /// Push one replica of `archive` onto `target` through the verified
    /// fill path; returns bytes moved. Must publish the new replica to
    /// the directory on success.
    fn replicate(&self, archive: &str, target: u32) -> Result<u64>;

    /// True while foreground fills are in flight — the idle gate.
    fn foreground_busy(&self) -> bool;

    /// Verify up to `max` least-recently-verified retained archives,
    /// stamping their last-verified times; returns how many were
    /// scanned.
    fn scrub_slice(&self, max: usize) -> usize;

    /// Outcome hook: a replica of `archive` landed on `target` (`bytes`
    /// moved; `was_orphan` when it had zero live sources). The runner's
    /// executor mirrors these into the target cache's counters so they
    /// flow through the normal snapshot/manifest/report path; mocks may
    /// ignore it.
    fn note_repair(&self, _archive: &str, _target: u32, _bytes: u64, _was_orphan: bool) {}

    /// Outcome hook: a repair of `archive` was abandoned (unknown size,
    /// over-budget, out of targets, or out of attempts).
    fn note_failure(&self, _archive: &str) {}
}

/// One queued repair. Ordered most-urgent-first: archives with zero
/// live sources before mere deficits, higher observed read counts
/// before lower, then FIFO for determinism.
#[derive(Debug, Clone, PartialEq, Eq)]
struct PendingRepair {
    /// No live source at enqueue time: every read is a GFS miss.
    orphaned: bool,
    /// Observed read count at enqueue time.
    reads: u32,
    /// Enqueue sequence (FIFO tie-break).
    seq: Reverse<u64>,
    name: String,
    attempts: u32,
}

impl Ord for PendingRepair {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.orphaned, self.reads, &self.seq)
            .cmp(&(other.orphaned, other.reads, &other.seq))
    }
}

impl PartialOrd for PendingRepair {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

#[derive(Default)]
struct QueueInner {
    heap: BinaryHeap<PendingRepair>,
    /// Names currently queued (dedup guard).
    queued: HashSet<String>,
    /// Monotonic enqueue counter.
    seq: u64,
}

/// Maintains per-archive replica targets and heals the cluster: see the
/// module docs for event sources, targets, and rate limits. All methods
/// are internally synchronized; the manager is shared between the
/// [`MaintenanceDaemon`] thread and whoever seeds popularity.
pub struct AvailabilityManager {
    directory: Arc<RetentionDirectory>,
    config: RepairConfig,
    /// archive name → observed read count (the popularity signal).
    popularity: Mutex<HashMap<String, u32>>,
    queue: Mutex<QueueInner>,
    repair_pushes: AtomicU64,
    repair_bytes: AtomicU64,
    orphan_repairs: AtomicU64,
    repair_failures: AtomicU64,
}

impl AvailabilityManager {
    /// Attach a manager to `directory` (enabling its replica-loss log)
    /// with the given knobs.
    pub fn new(directory: Arc<RetentionDirectory>, config: RepairConfig) -> AvailabilityManager {
        directory.enable_orphan_tracking();
        AvailabilityManager {
            directory,
            config,
            popularity: Mutex::new(HashMap::new()),
            queue: Mutex::new(QueueInner::default()),
            repair_pushes: AtomicU64::new(0),
            repair_bytes: AtomicU64::new(0),
            orphan_repairs: AtomicU64::new(0),
            repair_failures: AtomicU64::new(0),
        }
    }

    /// The knobs this manager runs with.
    pub fn config(&self) -> &RepairConfig {
        &self.config
    }

    /// Seed (or refresh) the popularity map from a run's learned
    /// placement — [`crate::cio::local_stage::StageRunner::seed_learned`]
    /// merges persisted manifest read counts with live ones, so a
    /// restarted runner knows last run's hot set before its first read.
    pub fn seed_popularity(&self, learned: &LearnedPlacement) {
        let mut pop = self.popularity.lock().unwrap();
        for ds in learned.iter() {
            let e = pop.entry(ds.name.clone()).or_insert(0);
            *e = (*e).max(ds.readers);
        }
    }

    /// Observed read count for `archive` (0 when never seen).
    pub fn read_count(&self, archive: &str) -> u32 {
        self.popularity.lock().unwrap().get(archive).copied().unwrap_or(0)
    }

    /// Live sources `archive` wants: [`RepairConfig::replica_target`]
    /// when popular, 1 otherwise.
    pub fn replica_target(&self, archive: &str) -> u32 {
        if self.read_count(archive) > self.config.popularity_threshold {
            self.config.replica_target.max(1)
        } else {
            1
        }
    }

    /// Replicas pushed so far.
    pub fn repair_pushes(&self) -> u64 {
        self.repair_pushes.load(Ordering::Relaxed)
    }

    /// Bytes moved by repair so far.
    pub fn repair_bytes(&self) -> u64 {
        self.repair_bytes.load(Ordering::Relaxed)
    }

    /// Repairs of archives that had *zero* live sources (every read was
    /// a GFS miss until the push landed).
    pub fn orphan_repairs(&self) -> u64 {
        self.orphan_repairs.load(Ordering::Relaxed)
    }

    /// Pushes abandoned after [`MAX_ATTEMPTS`] failures, plus archives
    /// found unrepairable (unknown size / larger than the tick budget).
    pub fn repair_failures(&self) -> u64 {
        self.repair_failures.load(Ordering::Relaxed)
    }

    /// Repairs currently queued.
    pub fn queue_len(&self) -> usize {
        self.queue.lock().unwrap().heap.len()
    }

    fn enqueue(&self, name: &str, attempts: u32) {
        let orphaned = self.directory.sources(name).is_empty();
        let reads = self.read_count(name);
        let mut q = self.queue.lock().unwrap();
        if !q.queued.insert(name.to_string()) {
            return;
        }
        q.seq += 1;
        let seq = Reverse(q.seq);
        q.heap.push(PendingRepair { orphaned, reads, seq, name: name.to_string(), attempts });
    }

    /// Drain the directory's replica-loss log into the queue. Eviction
    /// of a *cold* archive is deliberately skipped — re-replicating it
    /// would undo the LRU; lease-expiry and scrub-drop losses always
    /// queue (their replica count shrank through failure, not policy).
    pub fn absorb_events(&self) {
        for (name, cause) in self.directory.drain_orphans() {
            if cause == OrphanCause::Eviction
                && self.read_count(&name) <= self.config.popularity_threshold
            {
                continue;
            }
            self.enqueue(&name, 0);
        }
    }

    /// Walk every observed-popular archive and queue those short of
    /// their replica target — the catch-all for deficits that never
    /// produced an orphan event.
    pub fn audit_deficits(&self) {
        let popular: Vec<String> = {
            let pop = self.popularity.lock().unwrap();
            pop.iter()
                .filter(|(_, &reads)| reads > self.config.popularity_threshold)
                .map(|(name, _)| name.clone())
                .collect()
        };
        for name in popular {
            let live = self.directory.sources(&name).len() as u32;
            if live < self.replica_target(&name) {
                self.enqueue(&name, 0);
            }
        }
    }

    /// One maintenance pass: absorb events, audit deficits, then — when
    /// foreground is idle — work the queue under the byte budget and
    /// in-flight cap. See the module docs for the full rate-limit
    /// contract.
    pub fn tick(&self, exec: &dyn RepairExecutor) -> TickOutcome {
        self.tick_inner(exec, false)
    }

    /// A shutdown drain tick: same budget, but ignores the idle gate so
    /// an event absorbed moments before shutdown still gets repaired.
    pub fn drain_tick(&self, exec: &dyn RepairExecutor) -> TickOutcome {
        self.tick_inner(exec, true)
    }

    fn tick_inner(&self, exec: &dyn RepairExecutor, ignore_busy: bool) -> TickOutcome {
        self.absorb_events();
        self.audit_deficits();
        let mut out = TickOutcome::default();
        if !ignore_busy && exec.foreground_busy() {
            out.deferred_busy = true;
            return out;
        }
        let mut launched = 0usize;
        while launched < self.config.max_inflight_per_tick.max(1) {
            let Some(pending) = self.pop() else { break };
            // Re-check the deficit at launch time: a racing foreground
            // fill may have re-published a source since enqueue.
            let live = self.directory.sources(&pending.name);
            if live.len() as u32 >= self.replica_target(&pending.name) {
                continue;
            }
            let Some(bytes) = exec.archive_bytes(&pending.name) else {
                // No copy anywhere to measure: unrepairable.
                self.repair_failures.fetch_add(1, Ordering::Relaxed);
                exec.note_failure(&pending.name);
                continue;
            };
            if bytes > self.config.byte_budget_per_tick {
                // Larger than a whole tick's budget: unrepairable under
                // this policy (mirrors the neighbor-transfer size cap).
                self.repair_failures.fetch_add(1, Ordering::Relaxed);
                exec.note_failure(&pending.name);
                continue;
            }
            if out.bytes + bytes > self.config.byte_budget_per_tick {
                // Budget exhausted: put it back for the next tick.
                self.enqueue(&pending.name, pending.attempts);
                break;
            }
            let target = exec
                .candidate_groups(&pending.name)
                .into_iter()
                .find(|g| !live.contains(g));
            let Some(target) = target else {
                self.fail_or_retry(exec, pending);
                launched += 1;
                continue;
            };
            match exec.replicate(&pending.name, target) {
                Ok(moved) => {
                    out.pushes += 1;
                    out.bytes += moved;
                    self.repair_pushes.fetch_add(1, Ordering::Relaxed);
                    self.repair_bytes.fetch_add(moved, Ordering::Relaxed);
                    if live.is_empty() {
                        self.orphan_repairs.fetch_add(1, Ordering::Relaxed);
                    }
                    exec.note_repair(&pending.name, target, moved, live.is_empty());
                }
                Err(_) => self.fail_or_retry(exec, pending),
            }
            launched += 1;
        }
        out
    }

    fn pop(&self) -> Option<PendingRepair> {
        let mut q = self.queue.lock().unwrap();
        let pending = q.heap.pop()?;
        q.queued.remove(&pending.name);
        Some(pending)
    }

    fn fail_or_retry(&self, exec: &dyn RepairExecutor, mut pending: PendingRepair) {
        pending.attempts += 1;
        if pending.attempts >= MAX_ATTEMPTS {
            self.repair_failures.fetch_add(1, Ordering::Relaxed);
            exec.note_failure(&pending.name);
        } else {
            self.enqueue(&pending.name, pending.attempts);
        }
    }
}

/// The background maintenance thread: ticks the manager every
/// [`RepairConfig::tick_ms`], runs a scrub slice every
/// [`RepairConfig::scrub_period_ms`], and drains gracefully on stop (see
/// the module docs). Owned by the
/// [`crate::cio::local_stage::StageRunner`]; dropping it stops it.
pub struct MaintenanceDaemon {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
    scrub_cycles: Arc<AtomicU64>,
}

impl MaintenanceDaemon {
    /// Start the daemon over `manager` and `exec`.
    pub fn start(
        manager: Arc<AvailabilityManager>,
        exec: Arc<dyn RepairExecutor>,
    ) -> MaintenanceDaemon {
        let stop = Arc::new(AtomicBool::new(false));
        let scrub_cycles = Arc::new(AtomicU64::new(0));
        let (stop2, cycles2) = (Arc::clone(&stop), Arc::clone(&scrub_cycles));
        let thread = std::thread::spawn(move || {
            let cfg = *manager.config();
            let mut last_scrub = Instant::now();
            loop {
                // Sliced sleep so stop() never waits a whole tick.
                let mut slept = Duration::ZERO;
                while slept < cfg.tick() {
                    if stop2.load(Ordering::Acquire) {
                        break;
                    }
                    let slice = cfg.tick().saturating_sub(slept).min(Duration::from_millis(20));
                    std::thread::sleep(slice);
                    slept += slice;
                }
                if stop2.load(Ordering::Acquire) {
                    break;
                }
                manager.tick(&*exec);
                if last_scrub.elapsed() >= cfg.scrub_period() {
                    exec.scrub_slice(cfg.scrub_batch.max(1));
                    cycles2.fetch_add(1, Ordering::Relaxed);
                    last_scrub = Instant::now();
                }
            }
            // Graceful drain: one final non-idle-gated, still-budgeted
            // tick, so a loss observed moments before shutdown is not
            // silently forgotten.
            manager.drain_tick(&*exec);
        });
        MaintenanceDaemon { stop, thread: Some(thread), scrub_cycles }
    }

    /// Scrub slices the daemon has run so far.
    pub fn scrub_cycles(&self) -> u64 {
        self.scrub_cycles.load(Ordering::Relaxed)
    }

    /// Stop the daemon: finish the in-flight tick, run the final drain
    /// tick, and join. Idempotent.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for MaintenanceDaemon {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn config() -> RepairConfig {
        RepairConfig {
            replica_target: 2,
            popularity_threshold: 1,
            byte_budget_per_tick: 100,
            max_inflight_per_tick: 2,
            tick_ms: 10,
            scrub_period_ms: 40,
            scrub_batch: 4,
        }
    }

    /// In-memory executor: replicate publishes to the directory like the
    /// real one, sizes come from a fixed table, failures are scripted.
    struct MockExec {
        directory: Arc<RetentionDirectory>,
        sizes: HashMap<String, u64>,
        candidates: Vec<u32>,
        fail: Mutex<HashMap<String, u32>>,
        busy: AtomicBool,
        replicated: Mutex<Vec<(String, u32)>>,
        scrubbed: AtomicUsize,
    }

    impl MockExec {
        fn new(directory: Arc<RetentionDirectory>) -> MockExec {
            MockExec {
                directory,
                sizes: HashMap::new(),
                candidates: vec![0, 1, 2, 3],
                fail: Mutex::new(HashMap::new()),
                busy: AtomicBool::new(false),
                replicated: Mutex::new(Vec::new()),
                scrubbed: AtomicUsize::new(0),
            }
        }
    }

    impl RepairExecutor for MockExec {
        fn candidate_groups(&self, archive: &str) -> Vec<u32> {
            let live = self.directory.sources(archive);
            self.candidates.iter().copied().filter(|g| !live.contains(g)).collect()
        }

        fn archive_bytes(&self, archive: &str) -> Option<u64> {
            self.sizes.get(archive).copied()
        }

        fn replicate(&self, archive: &str, target: u32) -> Result<u64> {
            let mut fail = self.fail.lock().unwrap();
            if let Some(n) = fail.get_mut(archive) {
                if *n > 0 {
                    *n -= 1;
                    anyhow::bail!("scripted failure");
                }
            }
            drop(fail);
            self.replicated.lock().unwrap().push((archive.to_string(), target));
            self.directory.publish(archive, target);
            Ok(self.sizes[archive])
        }

        fn foreground_busy(&self) -> bool {
            self.busy.load(Ordering::Relaxed)
        }

        fn scrub_slice(&self, max: usize) -> usize {
            self.scrubbed.fetch_add(max, Ordering::Relaxed);
            max
        }
    }

    fn hot(mgr: &AvailabilityManager, name: &str, reads: u32, bytes: u64) {
        let mut learned = LearnedPlacement::new();
        learned.record_reads(name, bytes, reads);
        mgr.seed_popularity(&learned);
    }

    #[test]
    fn orphan_events_repair_most_urgent_first() {
        let d = Arc::new(RetentionDirectory::new(4));
        // One in-flight slot per tick: priority order is observable.
        let mut cfg = config();
        cfg.max_inflight_per_tick = 1;
        let mgr = AvailabilityManager::new(Arc::clone(&d), cfg);
        let mut exec = MockExec::new(Arc::clone(&d));
        exec.sizes.insert("hot.cioar".into(), 10);
        exec.sizes.insert("warm.cioar".into(), 10);
        hot(&mgr, "hot.cioar", 64, 10);
        hot(&mgr, "warm.cioar", 8, 10);

        // Sole source of both dies.
        d.publish("hot.cioar", 1);
        d.publish("warm.cioar", 1);
        d.renew_lease(1, Duration::from_millis(0));
        std::thread::sleep(Duration::from_millis(5));
        d.expire_overdue();

        let out = mgr.tick(&exec);
        assert_eq!(out.pushes, 1);
        assert_eq!(exec.replicated.lock().unwrap()[0].0, "hot.cioar", "hotter orphan first");
        assert_eq!(mgr.orphan_repairs(), 1, "zero live sources at push time");
        let out = mgr.tick(&exec);
        assert_eq!(out.pushes, 1);
        assert_eq!(exec.replicated.lock().unwrap()[1].0, "warm.cioar");
        assert_eq!(mgr.repair_pushes(), 2);
        assert_eq!(mgr.repair_bytes(), 20);
        assert_eq!(mgr.repair_failures(), 0);
    }

    #[test]
    fn audit_tops_popular_archives_up_to_target_and_stops() {
        let d = Arc::new(RetentionDirectory::new(4));
        let mgr = AvailabilityManager::new(Arc::clone(&d), config());
        let mut exec = MockExec::new(Arc::clone(&d));
        exec.sizes.insert("hot.cioar".into(), 10);
        hot(&mgr, "hot.cioar", 64, 10);
        d.publish("hot.cioar", 0);

        // One live source, target 2: the audit finds the deficit with no
        // orphan event at all.
        let out = mgr.tick(&exec);
        assert_eq!(out.pushes, 1);
        assert_eq!(d.sources("hot.cioar").len(), 2);
        // At target: steady state is quiet.
        let out = mgr.tick(&exec);
        assert_eq!(out.pushes, 0);
        assert_eq!(mgr.queue_len(), 0);
    }

    #[test]
    fn cold_eviction_is_not_repaired_but_lease_expiry_is() {
        let d = Arc::new(RetentionDirectory::new(4));
        let mgr = AvailabilityManager::new(Arc::clone(&d), config());
        let mut exec = MockExec::new(Arc::clone(&d));
        exec.sizes.insert("cold.cioar".into(), 10);

        // Evicting the last replica of a cold archive is normal LRU
        // churn: absorbed, not queued.
        d.publish("cold.cioar", 0);
        d.withdraw("cold.cioar", 0);
        let out = mgr.tick(&exec);
        assert_eq!(out.pushes, 0);
        assert_eq!(mgr.queue_len(), 0);

        // The same cold archive lost to lease expiry *is* repaired: its
        // replica vanished through failure, not policy.
        d.publish("cold.cioar", 1);
        d.renew_lease(1, Duration::from_millis(0));
        std::thread::sleep(Duration::from_millis(5));
        d.expire_overdue();
        let out = mgr.tick(&exec);
        assert_eq!(out.pushes, 1);
        assert_eq!(mgr.orphan_repairs(), 1);
    }

    #[test]
    fn byte_budget_caps_each_tick_and_carries_the_rest() {
        let d = Arc::new(RetentionDirectory::new(4));
        let mut cfg = config();
        cfg.byte_budget_per_tick = 100;
        cfg.max_inflight_per_tick = 8;
        let mgr = AvailabilityManager::new(Arc::clone(&d), cfg);
        let mut exec = MockExec::new(Arc::clone(&d));
        for name in ["a.cioar", "b.cioar", "c.cioar"] {
            exec.sizes.insert(name.into(), 60);
            hot(&mgr, name, 64, 60);
        }
        let out = mgr.tick(&exec);
        assert_eq!(out.pushes, 1, "second 60-byte push would blow the 100-byte budget");
        assert!(out.bytes <= 100);
        let out = mgr.tick(&exec);
        assert_eq!(out.pushes, 1);
        let out = mgr.tick(&exec);
        assert_eq!(out.pushes, 1, "the carried-over repairs land on later ticks");
        assert_eq!(mgr.repair_pushes(), 3);
        // Keep ticking until every archive reaches its 2-replica target.
        for _ in 0..3 {
            assert_eq!(mgr.tick(&exec).pushes, 1);
        }
        assert_eq!(mgr.tick(&exec).pushes, 0, "steady state");

        // An archive bigger than the whole budget is unrepairable, not a
        // queue wedge.
        exec.sizes.insert("huge.cioar".into(), 1000);
        hot(&mgr, "huge.cioar", 64, 1000);
        let before = mgr.repair_failures();
        let out = mgr.tick(&exec);
        assert_eq!(out.pushes, 0);
        assert_eq!(mgr.repair_failures(), before + 1);
        assert_eq!(mgr.queue_len(), 0);
    }

    #[test]
    fn busy_foreground_defers_movement_but_not_absorption() {
        let d = Arc::new(RetentionDirectory::new(4));
        let mgr = AvailabilityManager::new(Arc::clone(&d), config());
        let mut exec = MockExec::new(Arc::clone(&d));
        exec.sizes.insert("hot.cioar".into(), 10);
        hot(&mgr, "hot.cioar", 64, 10);
        d.publish("hot.cioar", 0);
        d.withdraw("hot.cioar", 0);

        exec.busy.store(true, Ordering::Relaxed);
        let out = mgr.tick(&exec);
        assert!(out.deferred_busy);
        assert_eq!(out.pushes, 0);
        assert_eq!(mgr.queue_len(), 1, "the event was still absorbed");

        exec.busy.store(false, Ordering::Relaxed);
        let out = mgr.tick(&exec);
        assert_eq!(out.pushes, 1);

        // drain_tick ignores the gate (shutdown path).
        let target = exec.replicated.lock().unwrap()[0].1;
        d.withdraw("hot.cioar", target);
        exec.busy.store(true, Ordering::Relaxed);
        let out = mgr.drain_tick(&exec);
        assert!(out.pushes >= 1);
    }

    #[test]
    fn failed_pushes_retry_with_bounded_attempts() {
        let d = Arc::new(RetentionDirectory::new(4));
        // One attempt per tick, one-replica targets: each tick is
        // exactly one retry, and a landed push ends the story.
        let mut cfg = config();
        cfg.max_inflight_per_tick = 1;
        cfg.replica_target = 1;
        let mgr = AvailabilityManager::new(Arc::clone(&d), cfg);
        let mut exec = MockExec::new(Arc::clone(&d));
        exec.sizes.insert("flaky.cioar".into(), 10);
        hot(&mgr, "flaky.cioar", 64, 10);
        exec.fail.lock().unwrap().insert("flaky.cioar".into(), 2);

        // Two scripted failures, then success on the third attempt.
        assert_eq!(mgr.tick(&exec).pushes, 0);
        assert_eq!(mgr.tick(&exec).pushes, 0);
        assert_eq!(mgr.tick(&exec).pushes, 1);
        assert_eq!(mgr.repair_failures(), 0, "retries that eventually land are not failures");

        // A persistent failure is dropped after MAX_ATTEMPTS.
        exec.sizes.insert("dead.cioar".into(), 10);
        hot(&mgr, "dead.cioar", 64, 10);
        exec.fail.lock().unwrap().insert("dead.cioar".into(), u32::MAX);
        for _ in 0..MAX_ATTEMPTS {
            mgr.tick(&exec);
        }
        assert_eq!(mgr.repair_failures(), 1);
        assert_eq!(mgr.queue_len(), 0, "no wedged queue");
    }

    #[test]
    fn daemon_ticks_scrubs_and_drains_on_stop() {
        let d = Arc::new(RetentionDirectory::new(4));
        let mgr = Arc::new(AvailabilityManager::new(Arc::clone(&d), config()));
        let mut exec = MockExec::new(Arc::clone(&d));
        exec.sizes.insert("hot.cioar".into(), 10);
        hot(&mgr, "hot.cioar", 64, 10);
        let exec: Arc<MockExec> = Arc::new(exec);

        d.publish("hot.cioar", 1);
        d.renew_lease(1, Duration::from_millis(0));
        std::thread::sleep(Duration::from_millis(5));
        d.expire_overdue();

        let mut daemon = MaintenanceDaemon::start(Arc::clone(&mgr), exec.clone());
        let deadline = Instant::now() + Duration::from_secs(5);
        while mgr.repair_pushes() == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(mgr.repair_pushes() >= 1, "daemon repaired the orphan");
        while daemon.scrub_cycles() == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(daemon.scrub_cycles() >= 1, "daemon ran a scrub slice");
        assert!(exec.scrubbed.load(Ordering::Relaxed) >= 1);

        // A loss just before stop is healed by the drain tick.
        let target = exec.replicated.lock().unwrap()[0].1;
        d.withdraw("hot.cioar", target);
        daemon.stop();
        daemon.stop(); // idempotent
        assert_eq!(
            d.sources("hot.cioar").len(),
            2,
            "shutdown drain repaired the final loss back to target"
        );
    }
}
