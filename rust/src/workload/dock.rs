//! The DOCK6-like molecular-docking workflow (§6.3, Figure 17).
//!
//! A database of ~15K candidate compounds is screened against receptor
//! proteins; each docking invocation averages 550 s and writes ~10 KB of
//! scores. The workflow has three stages:
//!
//! 1. **dock** — one task per compound: read input, compute, write output
//!    (parallel across all processors);
//! 2. **summarize** — summarize / sort / select the results. GPFS: a
//!    single process on a login node reading 15K small files from GFS.
//!    CIO: parallelized across processors, data local to the IFSs
//!    (the paper's 11.7× stage win: 694 s → 59 s);
//! 3. **archive** — pack results and store them on GFS (1.5× with CIO).
//!
//! Stage 1 runs through the full simulator (metadata contention, staging,
//! collector). Stages 2 and 3 use calibrated analytic models on top of
//! the same configuration constants — the paper gives their end-to-end
//! times, and their structure (per-file GFS scan vs parallel IFS scan +
//! serial merge) is what we model; see DESIGN.md §2.
//!
//! The compound *compute* payload in the end-to-end example
//! (`examples/dock_screening.rs`) is the real PJRT-executed docking-score
//! model from `python/compile/`; in the simulator the payload is the
//! measured duration profile.

use crate::config::ClusterConfig;
use crate::metrics::Report;
use crate::sim::cluster::{DurationModel, IoMode, SimCluster, TaskSpec};
use crate::util::table::{num, Table};
use crate::util::units::kib;

/// Per-file processing costs for the analytic stage-2/3 models.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageCosts {
    /// Seconds to open+read one small output file from GFS on the login
    /// node (metadata + small read under ambient load).
    pub gfs_per_file_s: f64,
    /// Seconds to read one member from an IFS-resident archive
    /// (random-access indexed read over the tree network).
    pub ifs_per_file_s: f64,
    /// Seconds for the login-node merge of one collector partial
    /// (sort/select of its summary).
    pub merge_per_partial_s: f64,
    /// Per-archive fixed cost in stage 3 (tar/xar packing + create).
    pub archive_fixed_s: f64,
}

impl Default for StageCosts {
    fn default() -> Self {
        StageCosts {
            gfs_per_file_s: 0.045,
            ifs_per_file_s: 0.003,
            merge_per_partial_s: 1.70,
            archive_fixed_s: 1.50,
        }
    }
}

/// The workflow parameters (§6.3's run: 15,351 compounds, 9 receptors,
/// 8K processors; outputs ~10 KB every ~550 s).
#[derive(Debug, Clone, PartialEq)]
pub struct DockWorkflow {
    /// Number of docking tasks (compounds × receptors partitions).
    pub tasks: u64,
    /// Mean docking duration (s).
    pub mean_dur_s: f64,
    /// Duration spread (sigma of the underlying normal).
    pub sigma: f64,
    /// Output bytes per task.
    pub out_bytes: u64,
    /// Input bytes per task (compound description + grid slice).
    pub in_bytes: u64,
    /// Analytic stage-2/3 cost constants.
    pub costs: StageCosts,
}

impl Default for DockWorkflow {
    fn default() -> Self {
        DockWorkflow {
            tasks: 15_360,
            mean_dur_s: 550.0,
            sigma: 0.10,
            out_bytes: kib(10),
            in_bytes: kib(100),
            costs: StageCosts::default(),
        }
    }
}

/// Stage-by-stage timing for one mode.
#[derive(Debug, Clone, PartialEq)]
pub struct DockResult {
    /// Mode label.
    pub mode: IoMode,
    /// Stage 1 (dock) wall-clock seconds.
    pub stage1_s: f64,
    /// Stage 2 (summarize/sort/select) seconds.
    pub stage2_s: f64,
    /// Stage 3 (archive) seconds.
    pub stage3_s: f64,
}

impl DockResult {
    /// Total workflow time.
    pub fn total_s(&self) -> f64 {
        self.stage1_s + self.stage2_s + self.stage3_s
    }
}

impl DockWorkflow {
    /// Task spec for stage 1.
    pub fn stage1_spec(&self) -> TaskSpec {
        TaskSpec {
            dur: DurationModel::LogNormal { mean_s: self.mean_dur_s, sigma: self.sigma },
            out_bytes: self.out_bytes,
            in_bytes: self.in_bytes,
            in_from_ifs: false,
        }
    }

    /// Run the full workflow in one mode on a fresh simulated partition.
    pub fn run(&self, cfg: &ClusterConfig, mode: IoMode) -> DockResult {
        // --- Stage 1: full simulation ---
        let mut cluster = SimCluster::new(cfg);
        let report = cluster.run_mtc_spec(self.tasks, &self.stage1_spec(), mode);
        // GPFS stage 1 ends when outputs are synchronously on GFS (that IS
        // task completion); CIO stage-1 tasks end at LFS→IFS commit, and
        // stage 2 can start then — data is already on the IFSs.
        let stage1_s = report.makespan_tasks_s;

        // --- Stage 2: summarize / sort / select ---
        let c = &self.costs;
        let stage2_s = match mode {
            IoMode::Gpfs => {
                // Single login-node process scanning every small file on
                // GFS (the paper's original implementation).
                self.tasks as f64 * c.gfs_per_file_s
            }
            IoMode::Cio | IoMode::RamOnly => {
                // Parallel scan: each collector's archive is processed on
                // its IFS (random-access reads), partials merged serially.
                let partials = cfg.ions().max(1) as f64;
                let files_per_partial = self.tasks as f64 / partials;
                files_per_partial * c.ifs_per_file_s + partials * c.merge_per_partial_s
            }
        };

        // --- Stage 3: archive results to GFS ---
        let total_bytes = self.tasks * self.out_bytes;
        let big_block_s = total_bytes as f64 / cfg.gfs.write_agg_bw;
        let stage3_s = match mode {
            IoMode::Gpfs => {
                // tar reads each small file back from GFS, then writes the
                // archive.
                self.tasks as f64 * c.gfs_per_file_s / 5.0 + big_block_s + c.archive_fixed_s
            }
            IoMode::Cio | IoMode::RamOnly => {
                // Re-read members from the IFS-resident archives (random
                // access), repack per ION, stream to GFS.
                self.tasks as f64 * c.ifs_per_file_s
                    + cfg.ions().max(1) as f64 * c.archive_fixed_s
                    + big_block_s
            }
        };

        DockResult { mode, stage1_s, stage2_s, stage3_s }
    }
}

/// Run CIO vs GPFS and produce the Figure 17 comparison report.
pub fn run_comparison(cfg: &ClusterConfig, tasks: u64) -> anyhow::Result<Report> {
    let wf = DockWorkflow { tasks, ..Default::default() };
    let gpfs = wf.run(cfg, IoMode::Gpfs);
    let cio = wf.run(cfg, IoMode::Cio);

    let mut table = Table::new(vec!["stage", "GPFS (s)", "CIO (s)", "speedup"])
        .title(format!("DOCK6 workflow, {} tasks on {} procs", tasks, cfg.procs));
    for (name, g, c) in [
        ("1: dock", gpfs.stage1_s, cio.stage1_s),
        ("2: summarize", gpfs.stage2_s, cio.stage2_s),
        ("3: archive", gpfs.stage3_s, cio.stage3_s),
        ("total", gpfs.total_s(), cio.total_s()),
    ] {
        table.row(vec![name.to_string(), num(g), num(c), format!("{:.2}x", g / c)]);
    }
    println!("{}", table.render());

    let mut report = Report::new("Figure 17: DOCK6 15K tasks on 8K processors");
    report.push("GPFS total", 2140.0, gpfs.total_s(), "s");
    report.push("CIO total", 1412.0, cio.total_s(), "s");
    report.push("stage2 GPFS", 694.0, gpfs.stage2_s, "s");
    report.push("stage2 CIO", 59.0, cio.stage2_s, "s");
    report.push("stage2 speedup", 11.7, gpfs.stage2_s / cio.stage2_s, "x");
    report.push("stage3 speedup", 1.5, gpfs.stage3_s / cio.stage3_s, "x");
    report.push("stage1 speedup", 1.06, gpfs.stage1_s / cio.stage1_s, "x");
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg8k() -> ClusterConfig {
        ClusterConfig::bgp(8192)
    }

    #[test]
    fn stage2_speedup_near_paper() {
        let wf = DockWorkflow::default();
        let cfg = cfg8k();
        let gpfs = wf.run(&cfg, IoMode::Gpfs);
        let cio = wf.run(&cfg, IoMode::Cio);
        let speedup = gpfs.stage2_s / cio.stage2_s;
        // Paper: 694 s -> 59 s = 11.7x. Shape check with slack.
        assert!((8.0..16.0).contains(&speedup), "stage2 speedup {speedup}");
        assert!((600.0..800.0).contains(&gpfs.stage2_s), "gpfs stage2 {}", gpfs.stage2_s);
        assert!((40.0..90.0).contains(&cio.stage2_s), "cio stage2 {}", cio.stage2_s);
    }

    #[test]
    fn stage3_modest_speedup() {
        let wf = DockWorkflow::default();
        let cfg = cfg8k();
        let gpfs = wf.run(&cfg, IoMode::Gpfs);
        let cio = wf.run(&cfg, IoMode::Cio);
        let speedup = gpfs.stage3_s / cio.stage3_s;
        assert!((1.1..2.5).contains(&speedup), "stage3 speedup {speedup}");
    }

    #[test]
    fn stage1_nearly_identical_compute_bound() {
        // 550 s tasks dwarf the IO: CIO stage-1 advantage should be small
        // (paper: 1.06x at 8K, 1.12x at 96K).
        let wf = DockWorkflow { tasks: 4096, ..Default::default() };
        let cfg = ClusterConfig::bgp(2048);
        let gpfs = wf.run(&cfg, IoMode::Gpfs);
        let cio = wf.run(&cfg, IoMode::Cio);
        let speedup = gpfs.stage1_s / cio.stage1_s;
        assert!((1.0..1.35).contains(&speedup), "stage1 speedup {speedup}");
    }

    #[test]
    fn totals_favor_cio() {
        let wf = DockWorkflow::default();
        let cfg = cfg8k();
        let gpfs = wf.run(&cfg, IoMode::Gpfs);
        let cio = wf.run(&cfg, IoMode::Cio);
        let speedup = gpfs.total_s() / cio.total_s();
        // Paper: 2140/1412 = 1.52x.
        assert!((1.2..2.0).contains(&speedup), "total speedup {speedup}");
    }
}
