//! Archive formats for collective output (§5.3).
//!
//! The prototype in the paper used `tar`; the design calls for `xar`,
//! whose updateable member directory records each member's byte offset so
//! later workflow stages can extract members **randomly and in parallel**.
//! We implement both as real on-disk formats:
//!
//! * [`Writer`] streams members and finishes with a footer-located member
//!   index (offset, size, CRC32, optional deflate) — functionally the
//!   xar idea with a zip-style trailer so archives remain append-friendly
//!   while being written;
//! * [`Reader`] opens the index and extracts members by name via `seek` —
//!   O(1) random access — including from multiple threads
//!   ([`Reader::extract_parallel`]);
//! * [`read_sequential`] is the tar-like fallback: scan the member stream
//!   in order, ignoring the index — used by the `ablation_archive` bench
//!   to quantify what xar buys over tar for stage-2 re-processing.
//!
//! Ingestion is pipelined and pooled (the PR-1 hot-path rework):
//!
//! * [`Writer::add_path`] / [`Writer::add_reader`] stream members in
//!   fixed-size chunks drawn from a shared [`BufferPool`], computing the
//!   CRC incrementally and deflating straight into the file — a multi-GiB
//!   member never materializes in memory. The header's
//!   length/CRC fields are back-patched with one seek once the member's
//!   true extent is known.
//! * [`Writer::add_paths_parallel`] is the parallel-compression pipeline:
//!   N workers read + deflate members concurrently
//!   ([`crate::util::pool::ordered_pipeline`]) while the single appender
//!   thread writes blobs strictly in submission order, so the on-disk
//!   member order (and therefore the index) is deterministic.
//!
//! Layout:
//!
//! ```text
//! [member]* [sums?] [index] [trailer]
//! member : MAGIC_MEMBER u32 | name_len u16 | name | flags u8 |
//!          raw_len u64 | stored_len u64 | crc32(raw) u32 | data
//! sums   : an ordinary member named `.cio-sums` (hidden) whose data is
//!          algo u8 | chunk u64 | data_end u64 | count u32 | sum u32 × count
//! index  : MAGIC_INDEX u32 | count u32 | entry*
//! entry  : name_len u16 | name | offset u64 | raw_len u64 |
//!          stored_len u64 | crc32 u32 | flags u8
//! trailer: index_offset u64 | archive_crc? (reserved u32 = 0) | MAGIC_TRAILER u32
//! ```
//!
//! All integers little-endian.
//!
//! Integrity (PR-8): the per-member CRC32 only validates a *whole*
//! member after extraction — a chunk-granular partial fill moves raw
//! archive byte ranges that cross member boundaries and never inflates
//! members. The hidden `.cio-sums` member closes that gap: it records a
//! CRC32 for every [`SUM_CHUNK`]-sized slice of the member region
//! `[0, data_end)`, so a receiver can verify any chunk-aligned byte span
//! on arrival ([`ChunkSums::verify_span`]) and a scrubber can re-verify a
//! retained file end to end ([`verify_archive`]). Hidden members (name
//! prefix `.cio-`) are reachable by exact-name lookup but excluded from
//! enumeration, so member counts and sequential scans are unchanged.
//! Archives written before PR-8 simply lack the member and verify as
//! [`Verification::Unchecked`]. The table is versioned by a leading
//! algorithm-id byte ([`SUM_ALGO_CRC32`]); readers reject unknown ids
//! with a typed error instead of misinterpreting a future keyed or
//! cryptographic hash's table as CRC32s.

use crate::util::pool::{ordered_pipeline, BufferPool, PooledBuf};
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::io::{BufReader, Read, Seek, SeekFrom, Write as IoWrite};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering as AtomicOrdering};
use std::sync::Arc;

const MAGIC_MEMBER: u32 = 0xC10A_0001;
const MAGIC_INDEX: u32 = 0xC10A_011D;
const MAGIC_TRAILER: u32 = 0xC10A_0E4D;

/// Chunk size for streamed member ingestion (and the pool's buffer size).
const CHUNK: usize = 256 * 1024;

/// Name prefix of hidden (bookkeeping) members: reachable via
/// [`Reader::entry`] / [`Reader::extract`] by exact name, but excluded
/// from [`Reader::entries`] / [`Reader::len`] / [`read_sequential`]
/// enumeration. Public member names may not start with it.
pub const HIDDEN_PREFIX: &str = ".cio-";

/// The hidden member holding the per-chunk checksum table.
pub const SUMS_MEMBER: &str = ".cio-sums";

/// Granularity of the per-chunk checksum table: one CRC32 per 4 KiB of
/// the member region (~0.1% space overhead). Small enough that every
/// fill-chunk size the partial-fill engine uses is a whole multiple, so
/// chunk-granular transfers verify without read amplification.
pub const SUM_CHUNK: u64 = 4096;

/// Algorithm id of a CRC32 checksum table — the first byte of the
/// [`SUMS_MEMBER`] payload. A keyed or cryptographic hash can slot in
/// under a new id without a format break; parsers reject ids they do
/// not implement ([`ChunkSums::parse`]).
pub const SUM_ALGO_CRC32: u8 = 0;

/// Cap on speculative pre-allocation from header-declared sizes. Actual
/// data may exceed this (buffers grow on demand); a corrupt header cannot
/// force a huge up-front allocation.
const PREALLOC_CAP: usize = 64 * 1024 * 1024;

/// Per-member compression flag.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Compression {
    /// Store raw bytes.
    None,
    /// Deflate (flate2) — the §7 "what role should compression play"
    /// question; benched in `ablation_compress`.
    Deflate,
}

impl Compression {
    fn flag(self) -> u8 {
        match self {
            Compression::None => 0,
            Compression::Deflate => 1,
        }
    }

    fn from_flag(f: u8) -> Result<Self> {
        match f {
            0 => Ok(Compression::None),
            1 => Ok(Compression::Deflate),
            other => bail!("unknown compression flag {other}"),
        }
    }
}

/// One member's index entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Entry {
    /// Member name (task output file name).
    pub name: String,
    /// Byte offset of the member header in the archive.
    pub offset: u64,
    /// Uncompressed size.
    pub raw_len: u64,
    /// Stored (possibly compressed) size.
    pub stored_len: u64,
    /// CRC32 of the raw bytes.
    pub crc32: u32,
    /// Compression used.
    pub compression: Compression,
}

/// Member-header length on disk for a given name length:
/// magic(4) name_len(2) name flags(1) raw_len(8) stored_len(8) crc(4).
fn member_header_len(name_len: usize) -> u64 {
    4 + 2 + name_len as u64 + 1 + 8 + 8 + 4
}

impl Entry {
    /// Archive-file offset of the member's first *data* byte (the stored
    /// bytes start right after the member header).
    pub fn data_offset(&self) -> u64 {
        self.offset + member_header_len(self.name.len())
    }

    /// Archive-file offset one past the member's last stored byte. With
    /// [`Entry::offset`] this bounds the byte extent a partial fill must
    /// materialize to read the member (header included).
    pub fn stored_end(&self) -> u64 {
        self.data_offset() + self.stored_len
    }
}

/// A compressed member produced by a pipeline worker, ready to append.
struct Blob {
    name: String,
    raw_len: u64,
    crc32: u32,
    compression: Compression,
    /// Stored (possibly compressed) bytes; the pooled buffer returns to
    /// the pool once the appender has written it out.
    data: PooledBuf,
}

/// Counts bytes flowing through an inner writer (measures the deflate
/// stream's stored length while it streams straight into the file).
struct CountingWriter<W> {
    inner: W,
    written: u64,
}

impl<W: IoWrite> IoWrite for CountingWriter<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let n = self.inner.write(buf)?;
        self.written += n as u64;
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

/// Streaming archive writer.
pub struct Writer<F: IoWrite + Seek> {
    file: F,
    entries: Vec<Entry>,
    names: BTreeMap<String, ()>,
    offset: u64,
    finished: bool,
    /// Set when an IO error left partial member bytes in the file that
    /// `offset` does not account for; all further writes (and `finish`)
    /// are refused so a corrupt index can never be emitted.
    poisoned: bool,
    /// The on-disk path when created via [`Writer::create`]: lets
    /// `finish` re-read the member region to build the `.cio-sums`
    /// checksum table (streamed members are header-back-patched, so the
    /// final bytes are only knowable from the file). `None` for generic
    /// sinks — those archives carry no sums member and verify as
    /// [`Verification::Unchecked`].
    source_path: Option<PathBuf>,
    pool: Arc<BufferPool>,
}

impl Writer<std::io::BufWriter<std::fs::File>> {
    /// Create an archive at `path`.
    pub fn create(path: &Path) -> Result<Self> {
        let f = std::fs::File::create(path)
            .with_context(|| format!("creating archive {}", path.display()))?;
        let mut w = Writer::new(std::io::BufWriter::new(f))?;
        w.source_path = Some(path.to_path_buf());
        Ok(w)
    }
}

impl<F: IoWrite + Seek> Writer<F> {
    /// Wrap any seekable sink.
    pub fn new(file: F) -> Result<Self> {
        Ok(Writer {
            file,
            entries: Vec::new(),
            names: BTreeMap::new(),
            offset: 0,
            finished: false,
            poisoned: false,
            source_path: None,
            pool: BufferPool::new(CHUNK, 16),
        })
    }

    /// Validate + reserve a member name.
    fn register(&mut self, name: &str) -> Result<()> {
        ensure!(!self.finished, "archive already finished");
        ensure!(!self.poisoned, "archive writer poisoned by an earlier IO error");
        ensure!(!name.is_empty() && name.len() <= u16::MAX as usize, "bad member name");
        ensure!(
            !name.starts_with(HIDDEN_PREFIX),
            "member name {name:?} collides with the hidden-member prefix {HIDDEN_PREFIX:?}"
        );
        ensure!(
            self.names.insert(name.to_string(), ()).is_none(),
            "duplicate member name {name:?}"
        );
        Ok(())
    }

    /// Poison the writer when a member write failed partway (the file may
    /// hold bytes `offset` does not account for) and pass the error on.
    fn poison_on_err<T>(&mut self, result: Result<T>) -> Result<T> {
        if result.is_err() {
            self.poisoned = true;
        }
        result
    }

    /// Write a complete member header. Placeholder lengths/CRC may be
    /// patched later by [`Writer::add_reader`].
    fn write_header(
        &mut self,
        name: &str,
        compression: Compression,
        raw_len: u64,
        stored_len: u64,
        crc: u32,
    ) -> Result<()> {
        let mut header = Vec::with_capacity(32 + name.len());
        header.extend_from_slice(&MAGIC_MEMBER.to_le_bytes());
        header.extend_from_slice(&(name.len() as u16).to_le_bytes());
        header.extend_from_slice(name.as_bytes());
        header.push(compression.flag());
        header.extend_from_slice(&raw_len.to_le_bytes());
        header.extend_from_slice(&stored_len.to_le_bytes());
        header.extend_from_slice(&crc.to_le_bytes());
        self.file.write_all(&header)?;
        Ok(())
    }

    /// Append one member from an in-memory slice.
    pub fn add(&mut self, name: &str, data: &[u8], compression: Compression) -> Result<()> {
        self.register(name)?;
        let result = self.add_slice_inner(name, data, compression);
        self.poison_on_err(result)
    }

    fn add_slice_inner(&mut self, name: &str, data: &[u8], compression: Compression) -> Result<()> {
        let crc = crc32fast::hash(data);
        let stored: std::borrow::Cow<[u8]> = match compression {
            Compression::None => data.into(),
            Compression::Deflate => {
                let mut enc =
                    flate2::write::DeflateEncoder::new(Vec::new(), flate2::Compression::fast());
                enc.write_all(data)?;
                enc.finish()?.into()
            }
        };
        let offset = self.offset;
        self.write_header(name, compression, data.len() as u64, stored.len() as u64, crc)?;
        self.file.write_all(&stored)?;
        self.entries.push(Entry {
            name: name.to_string(),
            offset,
            raw_len: data.len() as u64,
            stored_len: stored.len() as u64,
            crc32: crc,
            compression,
        });
        self.offset += member_header_len(name.len()) + stored.len() as u64;
        Ok(())
    }

    /// Append one member by streaming from any reader: fixed-size chunks
    /// through a pooled buffer, CRC computed incrementally, deflate output
    /// flowing straight into the archive. Memory use is O(chunk), not
    /// O(member) — the header's length/CRC fields are back-patched once
    /// the stream ends.
    pub fn add_reader(
        &mut self,
        name: &str,
        reader: &mut dyn Read,
        compression: Compression,
    ) -> Result<()> {
        self.register(name)?;
        let result = self.add_reader_inner(name, reader, compression);
        self.poison_on_err(result)
    }

    fn add_reader_inner(
        &mut self,
        name: &str,
        reader: &mut dyn Read,
        compression: Compression,
    ) -> Result<()> {
        let member_offset = self.offset;
        // Placeholder lengths + CRC, patched below.
        self.write_header(name, compression, 0, 0, 0)?;

        let mut chunk = BufferPool::get(&self.pool);
        chunk.resize(self.pool.chunk_size(), 0);
        let mut counter = CountingWriter { inner: &mut self.file, written: 0 };
        let (raw_len, crc) = stream_into(reader, &mut chunk, compression, &mut counter)?;
        let stored_len = counter.written;
        drop(chunk);

        // Patch raw_len / stored_len / crc now that they are known, then
        // return to the end of the member.
        let patch_offset = member_offset + 4 + 2 + name.len() as u64 + 1;
        let mut patch = [0u8; 20];
        patch[0..8].copy_from_slice(&raw_len.to_le_bytes());
        patch[8..16].copy_from_slice(&stored_len.to_le_bytes());
        patch[16..20].copy_from_slice(&crc.to_le_bytes());
        self.file.seek(SeekFrom::Start(patch_offset))?;
        self.file.write_all(&patch)?;
        let end = member_offset + member_header_len(name.len()) + stored_len;
        self.file.seek(SeekFrom::Start(end))?;

        self.entries.push(Entry {
            name: name.to_string(),
            offset: member_offset,
            raw_len,
            stored_len,
            crc32: crc,
            compression,
        });
        self.offset = end;
        Ok(())
    }

    /// Add a member by streaming a file from disk (never slurps it).
    pub fn add_path(&mut self, name: &str, path: &Path, compression: Compression) -> Result<()> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("reading member {}", path.display()))?;
        let mut reader = BufReader::with_capacity(CHUNK, f);
        self.add_reader(name, &mut reader, compression)
    }

    /// Append many file members through the parallel-compression
    /// pipeline: up to `threads` workers read + compress concurrently,
    /// while this thread appends the finished blobs **in `members`
    /// order** — the archive layout is identical to a sequential
    /// [`Writer::add_path`] loop, only faster. On the first error no
    /// further member is appended or claimed by a worker (compressions
    /// already in flight drain), and that first error is returned.
    pub fn add_paths_parallel(
        &mut self,
        members: &[(String, PathBuf)],
        compression: Compression,
        threads: usize,
    ) -> Result<()> {
        if threads <= 1 || members.len() <= 1 {
            for (name, path) in members {
                self.add_path(name, path, compression)?;
            }
            return Ok(());
        }
        let pool = self.pool.clone();
        let jobs: Vec<&(String, PathBuf)> = members.iter().collect();
        let abort = AtomicBool::new(false);
        let mut result: Result<()> = Ok(());
        ordered_pipeline(
            jobs,
            threads,
            |(name, path)| {
                if abort.load(AtomicOrdering::Relaxed) {
                    bail!("member {name:?} skipped after an earlier failure");
                }
                compress_member(&pool, name, path, compression)
            },
            |blob: Result<Blob>| {
                if result.is_ok() {
                    result = blob.and_then(|b| self.append_blob(b));
                    if result.is_err() {
                        abort.store(true, AtomicOrdering::Relaxed);
                    }
                }
            },
        );
        result
    }

    /// Append a worker-compressed blob (single appender: preserves order).
    fn append_blob(&mut self, blob: Blob) -> Result<()> {
        self.register(&blob.name)?;
        let result = self.append_blob_inner(blob);
        self.poison_on_err(result)
    }

    fn append_blob_inner(&mut self, blob: Blob) -> Result<()> {
        let offset = self.offset;
        self.write_header(
            &blob.name,
            blob.compression,
            blob.raw_len,
            blob.data.len() as u64,
            blob.crc32,
        )?;
        self.file.write_all(&blob.data)?;
        self.offset += member_header_len(blob.name.len()) + blob.data.len() as u64;
        self.entries.push(Entry {
            name: blob.name,
            offset,
            raw_len: blob.raw_len,
            stored_len: blob.data.len() as u64,
            crc32: blob.crc32,
            compression: blob.compression,
        });
        Ok(())
    }

    /// Members written so far.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no members were added yet.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Bytes written so far (members only; index not included).
    pub fn bytes_written(&self) -> u64 {
        self.offset
    }

    /// Write the index + trailer and flush. Returns the entry table.
    pub fn finish(mut self) -> Result<Vec<Entry>> {
        ensure!(!self.finished, "archive already finished");
        ensure!(
            !self.poisoned,
            "archive writer poisoned by an earlier IO error; refusing to write an index \
             over partial member bytes"
        );
        self.finished = true;
        // Append the hidden per-chunk checksum table covering every
        // member byte written so far. Only possible for path-backed
        // writers (streamed members back-patch their headers, so the
        // final bytes must be re-read from the file); generic sinks
        // produce a legacy archive that verifies as `Unchecked`.
        if let Some(path) = self.source_path.clone() {
            if !self.entries.is_empty() {
                let data_end = self.offset;
                self.file.flush()?;
                let mut f = std::fs::File::open(&path)
                    .with_context(|| format!("re-reading {} for checksums", path.display()))?;
                let sums = ChunkSums::compute(&mut f, data_end, SUM_CHUNK)?;
                let encoded = sums.encode();
                let result = self.add_slice_inner(SUMS_MEMBER, &encoded, Compression::None);
                self.poison_on_err(result)?;
            }
        }
        let index_offset = self.offset;
        let mut idx = Vec::new();
        idx.extend_from_slice(&MAGIC_INDEX.to_le_bytes());
        idx.extend_from_slice(&(self.entries.len() as u32).to_le_bytes());
        for e in &self.entries {
            idx.extend_from_slice(&(e.name.len() as u16).to_le_bytes());
            idx.extend_from_slice(e.name.as_bytes());
            idx.extend_from_slice(&e.offset.to_le_bytes());
            idx.extend_from_slice(&e.raw_len.to_le_bytes());
            idx.extend_from_slice(&e.stored_len.to_le_bytes());
            idx.extend_from_slice(&e.crc32.to_le_bytes());
            idx.push(e.compression.flag());
        }
        idx.extend_from_slice(&index_offset.to_le_bytes());
        idx.extend_from_slice(&0u32.to_le_bytes()); // reserved
        idx.extend_from_slice(&MAGIC_TRAILER.to_le_bytes());
        self.file.write_all(&idx)?;
        self.file.flush()?;
        Ok(self.entries)
    }
}

/// The single chunked-ingestion loop every write path shares: stream
/// `reader` through `chunk`-sized reads into `sink` (deflating when
/// asked), hashing the raw bytes incrementally. Returns
/// `(raw_len, crc32)`; the caller measures stored bytes at the sink.
fn stream_into(
    reader: &mut dyn Read,
    chunk: &mut [u8],
    compression: Compression,
    sink: &mut dyn IoWrite,
) -> Result<(u64, u32)> {
    let mut hasher = crc32fast::Hasher::new();
    let mut raw_len = 0u64;
    match compression {
        Compression::None => loop {
            let n = reader.read(chunk)?;
            if n == 0 {
                break;
            }
            hasher.update(&chunk[..n]);
            sink.write_all(&chunk[..n])?;
            raw_len += n as u64;
        },
        Compression::Deflate => {
            let mut enc = flate2::write::DeflateEncoder::new(sink, flate2::Compression::fast());
            loop {
                let n = reader.read(chunk)?;
                if n == 0 {
                    break;
                }
                hasher.update(&chunk[..n]);
                enc.write_all(&chunk[..n])?;
                raw_len += n as u64;
            }
            enc.finish()?;
        }
    }
    Ok((raw_len, hasher.finalize()))
}

/// Pipeline worker: read `path` in pooled chunks, CRC incrementally,
/// compress into a pooled output buffer.
fn compress_member(
    pool: &Arc<BufferPool>,
    name: &str,
    path: &Path,
    compression: Compression,
) -> Result<Blob> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("reading member {}", path.display()))?;
    let mut reader = BufReader::with_capacity(pool.chunk_size(), f);
    let mut chunk = BufferPool::get(pool);
    chunk.resize(pool.chunk_size(), 0);
    let mut out = BufferPool::get(pool);
    let (raw_len, crc32) = stream_into(&mut reader, &mut chunk, compression, &mut *out)?;
    Ok(Blob { name: name.to_string(), raw_len, crc32, compression, data: out })
}

/// Random-access archive reader.
pub struct Reader {
    path: PathBuf,
    /// All entries, visible first (stable order), hidden at the tail.
    entries: Vec<Entry>,
    /// Count of visible (non-`.cio-`) entries at the front of `entries`.
    visible: usize,
    by_name: BTreeMap<String, usize>,
}

impl Reader {
    /// Open an archive, parse its index from the trailer, and validate
    /// that every entry's extent lies inside the member region (a corrupt
    /// index cannot direct reads past EOF or demand absurd allocations).
    pub fn open(path: &Path) -> Result<Reader> {
        Self::open_indexed_range(path, &mut |_, _| Ok(()))
    }

    /// [`Reader::open`] over a **partially-resident** file: before every
    /// read of a byte range, `materialize(offset, len)` is called so the
    /// caller (the partial-fill engine) can fetch the covering chunks
    /// first. The trailer and index live at the archive tail, so
    /// mounting an index costs exactly two materialized extents — the
    /// 16-byte trailer, then `[index_offset, len - 16)` — and the rest of
    /// the archive can stay absent. On a fully-resident file the no-op
    /// callback makes this identical to [`Reader::open`].
    pub fn open_indexed_range(
        path: &Path,
        materialize: &mut dyn FnMut(u64, u64) -> Result<()>,
    ) -> Result<Reader> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening archive {}", path.display()))?;
        let len = f.metadata()?.len();
        ensure!(len >= 16, "archive too short ({len} bytes)");
        materialize(len - 16, 16).context("materializing the archive trailer")?;
        f.seek(SeekFrom::End(-16))?;
        let mut trailer = [0u8; 16];
        f.read_exact(&mut trailer)?;
        let index_offset = u64::from_le_bytes(trailer[0..8].try_into().unwrap());
        let magic = u32::from_le_bytes(trailer[12..16].try_into().unwrap());
        ensure!(magic == MAGIC_TRAILER, "bad trailer magic {magic:#x}");
        // The index region must fit between the members and the trailer
        // (`<=` rather than `< len`: an offset inside the trailer would
        // underflow the region length below).
        ensure!(index_offset <= len - 16, "index offset {index_offset} beyond EOF {len}");
        materialize(index_offset, len - 16 - index_offset)
            .context("materializing the archive index")?;
        f.seek(SeekFrom::Start(index_offset))?;
        let mut index_bytes = vec![0u8; (len - 16 - index_offset) as usize];
        f.read_exact(&mut index_bytes)?;
        let (entries, visible, by_name) = parse_index(&index_bytes, index_offset)?;
        Ok(Reader { path: path.to_path_buf(), entries, visible, by_name })
    }

    /// Visible member entries in archive order (hidden `.cio-`
    /// bookkeeping members are excluded; look those up by exact name).
    pub fn entries(&self) -> &[Entry] {
        &self.entries[..self.visible]
    }

    /// Number of visible members.
    pub fn len(&self) -> usize {
        self.visible
    }

    /// True when the archive holds no visible members.
    pub fn is_empty(&self) -> bool {
        self.visible == 0
    }

    /// The per-chunk checksum table, if this archive carries one.
    /// Loading goes through [`Reader::extract`], so the table itself is
    /// member-CRC-validated before anything trusts it.
    pub fn chunk_sums(&self) -> Result<Option<ChunkSums>> {
        if self.entry(SUMS_MEMBER).is_none() {
            return Ok(None);
        }
        let raw = self.extract(SUMS_MEMBER)?;
        Ok(Some(ChunkSums::parse(&raw)?))
    }

    /// Look up a member by name.
    pub fn entry(&self, name: &str) -> Option<&Entry> {
        self.by_name.get(name).map(|&i| &self.entries[i])
    }

    /// Extract one member by name (random access: one seek + one read).
    pub fn extract(&self, name: &str) -> Result<Vec<u8>> {
        let entry = self.entry(name).with_context(|| format!("no member {name:?}"))?;
        let mut f = std::fs::File::open(&self.path)?;
        let mut scratch = Vec::new();
        let mut out = Vec::new();
        Self::read_member(&mut f, entry, &mut scratch, &mut out)?;
        Ok(out)
    }

    /// Read `len` bytes at `offset` within member `name` — the member-
    /// range random access a stage task uses to pick records out of a
    /// cached (IFS-retained) archive without extracting the whole member.
    /// The range is clamped to the member's length, so a read at EOF
    /// returns an empty vec.
    ///
    /// For `Compression::None` members this is one seek + one read of
    /// exactly the requested extent; note that a partial read cannot be
    /// CRC-verified (the checksum covers the whole member — use
    /// [`Reader::extract`] when integrity matters more than IO). Deflate
    /// members have no random-access substructure, so the member is
    /// inflated (and CRC-checked) and the range sliced out.
    pub fn extract_range(&self, name: &str, offset: u64, len: usize) -> Result<Vec<u8>> {
        let entry = self.entry(name).with_context(|| format!("no member {name:?}"))?;
        let start = offset.min(entry.raw_len);
        let take = (len as u64).min(entry.raw_len - start) as usize;
        if take == 0 {
            return Ok(Vec::new());
        }
        match entry.compression {
            Compression::None => {
                let mut f = std::fs::File::open(&self.path)?;
                let data_start = entry.offset + member_header_len(entry.name.len());
                f.seek(SeekFrom::Start(data_start + start))?;
                let mut out = vec![0u8; take];
                f.read_exact(&mut out)
                    .with_context(|| format!("range read of member {name:?}"))?;
                Ok(out)
            }
            Compression::Deflate => {
                let whole = self.extract(name)?;
                Ok(whole[start as usize..start as usize + take].to_vec())
            }
        }
    }

    /// Read one member into `out` given an already-open handle. `scratch`
    /// and `out` are caller-owned so parallel extraction reuses one pair
    /// per worker thread instead of allocating per member.
    fn read_member(
        f: &mut std::fs::File,
        entry: &Entry,
        scratch: &mut Vec<u8>,
        out: &mut Vec<u8>,
    ) -> Result<()> {
        let header_len = member_header_len(entry.name.len()) as usize;
        f.seek(SeekFrom::Start(entry.offset))?;
        scratch.clear();
        scratch.resize(header_len, 0);
        f.read_exact(&mut scratch[..])?;
        let magic = u32::from_le_bytes(scratch[0..4].try_into().unwrap());
        ensure!(magic == MAGIC_MEMBER, "bad member magic at {}", entry.offset);
        match entry.compression {
            Compression::None => {
                out.clear();
                out.resize(entry.stored_len as usize, 0);
                f.read_exact(&mut out[..])?;
            }
            Compression::Deflate => {
                scratch.clear();
                scratch.resize(entry.stored_len as usize, 0);
                f.read_exact(&mut scratch[..])?;
                out.clear();
                out.reserve((entry.raw_len as usize).min(PREALLOC_CAP));
                flate2::read::DeflateDecoder::new(&scratch[..])
                    .read_to_end(out)
                    .with_context(|| format!("inflating member {}", entry.name))?;
            }
        }
        ensure!(out.len() as u64 == entry.raw_len, "length mismatch for {}", entry.name);
        let crc = crc32fast::hash(out);
        ensure!(crc == entry.crc32, "CRC mismatch for {} (corrupt archive)", entry.name);
        Ok(())
    }

    /// Extract every member with `threads` workers; `visit` is called with
    /// `(name, bytes)` from worker threads. This is the §5.3 parallel
    /// re-processing path that the indexed format enables. Each worker
    /// keeps one file handle and one reused buffer pair for its whole run.
    pub fn extract_parallel(
        &self,
        threads: usize,
        visit: impl Fn(&str, &[u8]) + Send + Sync,
    ) -> Result<()> {
        let threads = threads.max(1).min(self.visible.max(1));
        let next = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let errors = std::sync::Mutex::new(Vec::<anyhow::Error>::new());
        std::thread::scope(|scope| {
            for _ in 0..threads {
                let next = next.clone();
                let errors = &errors;
                let visit = &visit;
                let entries = &self.entries[..self.visible];
                let path = &self.path;
                scope.spawn(move || {
                    let mut f = match std::fs::File::open(path) {
                        Ok(f) => f,
                        Err(e) => {
                            errors.lock().unwrap().push(e.into());
                            return;
                        }
                    };
                    let mut scratch = Vec::new();
                    let mut out = Vec::new();
                    loop {
                        let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if i >= entries.len() {
                            break;
                        }
                        match Self::read_member(&mut f, &entries[i], &mut scratch, &mut out) {
                            Ok(()) => visit(&entries[i].name, &out),
                            Err(e) => {
                                errors.lock().unwrap().push(e);
                                break;
                            }
                        }
                    }
                });
            }
        });
        let errs = errors.into_inner().unwrap();
        if let Some(e) = errs.into_iter().next() {
            return Err(e);
        }
        Ok(())
    }
}

/// Parse the index region bytes (everything in `[index_offset, EOF-16)`)
/// into the entry table, validating every extent against the member
/// region `[0, index_offset)` before trusting it — shared by
/// [`Reader::open`] and [`Reader::open_indexed_range`].
fn parse_index(
    index_bytes: &[u8],
    index_offset: u64,
) -> Result<(Vec<Entry>, usize, BTreeMap<String, usize>)> {
    let mut cur = index_bytes;
    let magic = read_u32(&mut cur)?;
    ensure!(magic == MAGIC_INDEX, "bad index magic {magic:#x}");
    let count = read_u32(&mut cur)? as usize;
    let mut entries = Vec::with_capacity(count.min(PREALLOC_CAP / 64));
    let mut by_name = BTreeMap::new();
    for i in 0..count {
        let name_len = read_u16(&mut cur)? as usize;
        ensure!(cur.len() >= name_len, "truncated index entry {i}");
        let name = std::str::from_utf8(&cur[..name_len])
            .context("non-utf8 member name")?
            .to_string();
        cur = &cur[name_len..];
        let offset = read_u64(&mut cur)?;
        let raw_len = read_u64(&mut cur)?;
        let stored_len = read_u64(&mut cur)?;
        let crc32 = read_u32(&mut cur)?;
        let flags = read_u8(&mut cur)?;
        let end = offset
            .checked_add(member_header_len(name_len))
            .and_then(|v| v.checked_add(stored_len))
            .with_context(|| format!("member {name:?}: extent overflows"))?;
        ensure!(
            end <= index_offset,
            "member {name:?} extends beyond the member region (corrupt index)"
        );
        entries.push(Entry {
            name,
            offset,
            raw_len,
            stored_len,
            crc32,
            compression: Compression::from_flag(flags)?,
        });
    }
    // Stable-partition visible members to the front so enumeration can
    // hand out a plain slice; hidden bookkeeping members sit at the tail,
    // reachable only by exact-name lookup.
    let (mut visible_entries, hidden): (Vec<Entry>, Vec<Entry>) =
        entries.into_iter().partition(|e| !e.name.starts_with(HIDDEN_PREFIX));
    let visible = visible_entries.len();
    visible_entries.extend(hidden);
    for (i, e) in visible_entries.iter().enumerate() {
        by_name.insert(e.name.clone(), i);
    }
    Ok((visible_entries, visible, by_name))
}

/// The per-chunk checksum table carried in the hidden [`SUMS_MEMBER`]:
/// one CRC32 per `chunk`-sized slice of the member region
/// `[0, data_end)` (the final slice may be short). This is what lets a
/// receiver verify *partial* transfers — chunk-aligned raw byte spans —
/// without inflating or even parsing members.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChunkSums {
    /// Checksum granularity in bytes (always [`SUM_CHUNK`] for archives
    /// we write; parsed archives may differ).
    pub chunk: u64,
    /// End of the covered member region — the sums member's own offset.
    pub data_end: u64,
    /// `data_end.div_ceil(chunk)` CRC32s, in chunk order.
    pub crcs: Vec<u32>,
}

impl ChunkSums {
    /// Compute the table by streaming `data_end` bytes from `reader`
    /// (positioned at archive offset 0).
    pub fn compute(reader: &mut dyn Read, data_end: u64, chunk: u64) -> Result<ChunkSums> {
        ensure!(chunk > 0, "zero checksum chunk");
        let mut crcs = Vec::with_capacity(data_end.div_ceil(chunk) as usize);
        let mut buf = vec![0u8; chunk as usize];
        let mut at = 0u64;
        while at < data_end {
            let n = chunk.min(data_end - at) as usize;
            reader
                .read_exact(&mut buf[..n])
                .with_context(|| format!("reading member region at {at} for checksums"))?;
            crcs.push(crc32fast::hash(&buf[..n]));
            at += n as u64;
        }
        Ok(ChunkSums { chunk, data_end, crcs })
    }

    /// Serialize for the hidden member.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(21 + self.crcs.len() * 4);
        out.push(SUM_ALGO_CRC32);
        out.extend_from_slice(&self.chunk.to_le_bytes());
        out.extend_from_slice(&self.data_end.to_le_bytes());
        out.extend_from_slice(&(self.crcs.len() as u32).to_le_bytes());
        for crc in &self.crcs {
            out.extend_from_slice(&crc.to_le_bytes());
        }
        out
    }

    /// Parse a hidden-member payload (validating internal consistency).
    /// An unknown algorithm id is a clean typed error, not a garbage
    /// table: a newer writer's keyed-hash sums must fail verification
    /// loudly rather than be misread as CRC32s.
    pub fn parse(data: &[u8]) -> Result<ChunkSums> {
        let mut cur = data;
        let algo = read_u8(&mut cur)?;
        ensure!(
            algo == SUM_ALGO_CRC32,
            "unsupported checksum algorithm id {algo} (this reader implements only \
             {SUM_ALGO_CRC32} = CRC32)"
        );
        let chunk = read_u64(&mut cur)?;
        let data_end = read_u64(&mut cur)?;
        let count = read_u32(&mut cur)? as usize;
        ensure!(chunk > 0, "zero checksum chunk");
        ensure!(
            count as u64 == data_end.div_ceil(chunk),
            "checksum table holds {count} entries for a {data_end}-byte region"
        );
        ensure!(cur.len() == count * 4, "truncated checksum table");
        let mut crcs = Vec::with_capacity(count);
        for _ in 0..count {
            crcs.push(read_u32(&mut cur)?);
        }
        Ok(ChunkSums { chunk, data_end, crcs })
    }

    /// Verify a raw archive byte span that arrived as `bytes` starting at
    /// archive offset `span_start`. Every sum chunk *fully* covered by
    /// the span is checked (the final short chunk counts as fully covered
    /// when the span reaches `data_end`); bytes past `data_end` — the
    /// sums member itself, the index, the trailer — are ignored, as are
    /// partially-covered edge chunks (their remaining bytes will be
    /// verified by the transfer that moves them). Errors name the first
    /// mismatching chunk.
    pub fn verify_span(&self, span_start: u64, bytes: &[u8]) -> Result<()> {
        let span_end = span_start + bytes.len() as u64;
        let covered_end = span_end.min(self.data_end);
        if span_start >= covered_end {
            return Ok(());
        }
        let mut check = |idx: u64| -> Result<()> {
            let cstart = idx * self.chunk;
            let cend = (cstart + self.chunk).min(self.data_end);
            let want = *self
                .crcs
                .get(idx as usize)
                .with_context(|| format!("checksum table too short for chunk {idx}"))?;
            let lo = (cstart - span_start) as usize;
            let hi = (cend - span_start) as usize;
            let got = crc32fast::hash(&bytes[lo..hi]);
            ensure!(
                got == want,
                "checksum mismatch in archive span [{cstart}, {cend}): \
                 got {got:#010x}, want {want:#010x}"
            );
            Ok(())
        };
        for idx in crate::cio::extent::chunks_within(span_start, covered_end, self.chunk) {
            check(idx)?;
        }
        // The final short chunk has no full-chunk extent; it is verifiable
        // exactly when the span covers through data_end.
        if self.data_end % self.chunk != 0 && covered_end == self.data_end {
            let tail = self.data_end / self.chunk;
            if tail * self.chunk >= span_start {
                check(tail)?;
            }
        }
        Ok(())
    }
}

/// What [`verify_archive`] concluded.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verification {
    /// Every member-region chunk matched the checksum table.
    Verified,
    /// The archive predates the checksum table (no hidden sums member);
    /// nothing to verify against.
    Unchecked,
}

/// Re-verify a complete on-disk archive against its checksum table — the
/// scrubber's primitive, and the whole-file check a fill runs after a
/// transfer lands. Returns [`Verification::Unchecked`] for legacy
/// archives without a table; errors on any mismatch (or IO failure),
/// naming the first bad chunk.
pub fn verify_archive(path: &Path) -> Result<Verification> {
    let r = Reader::open(path)?;
    let Some(sums) = r.chunk_sums()? else {
        return Ok(Verification::Unchecked);
    };
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening {} for verification", path.display()))?;
    let mut buf = vec![0u8; sums.chunk as usize];
    for (i, &want) in sums.crcs.iter().enumerate() {
        let start = i as u64 * sums.chunk;
        let n = sums.chunk.min(sums.data_end - start) as usize;
        f.read_exact(&mut buf[..n])
            .with_context(|| format!("reading chunk {i} of {}", path.display()))?;
        let got = crc32fast::hash(&buf[..n]);
        ensure!(
            got == want,
            "checksum mismatch in {} at [{start}, {}): got {got:#010x}, want {want:#010x}",
            path.display(),
            start + n as u64,
        );
    }
    Ok(Verification::Verified)
}

/// Tar-like sequential scan: read members in order without the index
/// (what stage 2 must do when the collector used a tar-style archive).
/// Visits `(name, raw bytes)`; verifies CRCs. Streams through a
/// [`BufReader`] — memory use is O(largest member), never O(archive), so
/// multi-GiB archives scan without slurping.
pub fn read_sequential(path: &Path, mut visit: impl FnMut(&str, &[u8])) -> Result<usize> {
    let f = std::fs::File::open(path)?;
    let mut r = BufReader::with_capacity(CHUNK, f);
    let mut count = 0usize;
    let mut stored = Vec::new();
    let mut raw = Vec::new();
    loop {
        let magic = match read_arr::<4>(&mut r) {
            Ok(b) => u32::from_le_bytes(b),
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
                bail!("truncated archive: no trailer found")
            }
            Err(e) => return Err(e.into()),
        };
        if magic == MAGIC_INDEX {
            return Ok(count); // reached the index: done
        }
        ensure!(magic == MAGIC_MEMBER, "bad member magic {magic:#x}");
        let name_len = u16::from_le_bytes(read_arr::<2>(&mut r)?) as usize;
        let mut name_buf = vec![0u8; name_len];
        r.read_exact(&mut name_buf).context("truncated member name")?;
        let name = String::from_utf8(name_buf).context("non-utf8 member name")?;
        let flags = read_arr::<1>(&mut r)?[0];
        let raw_len = u64::from_le_bytes(read_arr::<8>(&mut r)?) as usize;
        let stored_len = u64::from_le_bytes(read_arr::<8>(&mut r)?) as usize;
        let crc = u32::from_le_bytes(read_arr::<4>(&mut r)?);
        // `take` + `read_to_end` grows with the bytes actually present, so
        // a corrupt stored_len cannot force a giant allocation.
        stored.clear();
        let got = (&mut r).take(stored_len as u64).read_to_end(&mut stored)?;
        ensure!(got == stored_len, "truncated member {name}");
        let data: &[u8] = match Compression::from_flag(flags)? {
            Compression::None => &stored,
            Compression::Deflate => {
                raw.clear();
                raw.reserve(raw_len.min(PREALLOC_CAP));
                flate2::read::DeflateDecoder::new(&stored[..])
                    .read_to_end(&mut raw)
                    .with_context(|| format!("inflating member {name}"))?;
                &raw
            }
        };
        ensure!(data.len() == raw_len, "length mismatch for {name}");
        ensure!(crc32fast::hash(data) == crc, "CRC mismatch for {name}");
        // Hidden bookkeeping members are verified (above) but not part of
        // the member stream a tar-style consumer sees.
        if !name.starts_with(HIDDEN_PREFIX) {
            visit(&name, data);
            count += 1;
        }
    }
}

fn read_arr<const N: usize>(r: &mut impl Read) -> std::io::Result<[u8; N]> {
    let mut b = [0u8; N];
    r.read_exact(&mut b)?;
    Ok(b)
}

fn read_u8(cur: &mut &[u8]) -> Result<u8> {
    ensure!(!cur.is_empty(), "truncated");
    let v = cur[0];
    *cur = &cur[1..];
    Ok(v)
}

fn read_u16(cur: &mut &[u8]) -> Result<u16> {
    ensure!(cur.len() >= 2, "truncated");
    let v = u16::from_le_bytes(cur[0..2].try_into().unwrap());
    *cur = &cur[2..];
    Ok(v)
}

fn read_u32(cur: &mut &[u8]) -> Result<u32> {
    ensure!(cur.len() >= 4, "truncated");
    let v = u32::from_le_bytes(cur[0..4].try_into().unwrap());
    *cur = &cur[4..];
    Ok(v)
}

fn read_u64(cur: &mut &[u8]) -> Result<u64> {
    ensure!(cur.len() >= 8, "truncated");
    let v = u64::from_le_bytes(cur[0..8].try_into().unwrap());
    *cur = &cur[8..];
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("cio-archive-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample_members(n: usize) -> Vec<(String, Vec<u8>)> {
        (0..n)
            .map(|i| {
                let name = format!("task-{i:04}.out");
                let data: Vec<u8> = (0..(i * 37 + 11)).map(|j| ((i * 131 + j * 7) % 251) as u8).collect();
                (name, data)
            })
            .collect()
    }

    #[test]
    fn roundtrip_random_access() {
        let dir = tmpdir("rt");
        let path = dir.join("a.cioar");
        let members = sample_members(20);
        let mut w = Writer::create(&path).unwrap();
        for (name, data) in &members {
            w.add(name, data, Compression::None).unwrap();
        }
        assert_eq!(w.len(), 20);
        w.finish().unwrap();

        let r = Reader::open(&path).unwrap();
        assert_eq!(r.len(), 20);
        // Random access in arbitrary order.
        for (name, data) in members.iter().rev() {
            assert_eq!(&r.extract(name).unwrap(), data);
        }
        assert!(r.extract("missing").is_err());
    }

    #[test]
    fn deflate_members_roundtrip_and_shrink() {
        let dir = tmpdir("z");
        let path = dir.join("z.cioar");
        let compressible = vec![b'x'; 100_000];
        let mut w = Writer::create(&path).unwrap();
        w.add("big.txt", &compressible, Compression::Deflate).unwrap();
        let entries = w.finish().unwrap();
        assert!(entries[0].stored_len < 10_000, "deflate should crush runs");
        let r = Reader::open(&path).unwrap();
        assert_eq!(r.extract("big.txt").unwrap(), compressible);
    }

    #[test]
    fn sequential_scan_matches() {
        let dir = tmpdir("seq");
        let path = dir.join("s.cioar");
        let members = sample_members(10);
        let mut w = Writer::create(&path).unwrap();
        for (name, data) in &members {
            w.add(name, data, Compression::None).unwrap();
        }
        w.finish().unwrap();
        let mut seen = Vec::new();
        let n = read_sequential(&path, |name, data| seen.push((name.to_string(), data.to_vec())))
            .unwrap();
        assert_eq!(n, 10);
        assert_eq!(seen, members);
    }

    #[test]
    fn parallel_extraction_sees_all_members() {
        let dir = tmpdir("par");
        let path = dir.join("p.cioar");
        let members = sample_members(64);
        let mut w = Writer::create(&path).unwrap();
        for (name, data) in &members {
            w.add(name, data, Compression::Deflate).unwrap();
        }
        w.finish().unwrap();
        let r = Reader::open(&path).unwrap();
        let seen = Mutex::new(std::collections::BTreeMap::new());
        r.extract_parallel(8, |name, data| {
            seen.lock().unwrap().insert(name.to_string(), data.to_vec());
        })
        .unwrap();
        let seen = seen.into_inner().unwrap();
        assert_eq!(seen.len(), 64);
        for (name, data) in &members {
            assert_eq!(&seen[name], data);
        }
    }

    #[test]
    fn range_reads_match_full_extraction() {
        let dir = tmpdir("range");
        let path = dir.join("r.cioar");
        let data: Vec<u8> = (0..10_000u32).map(|i| (i % 251) as u8).collect();
        let mut w = Writer::create(&path).unwrap();
        w.add("plain", &data, Compression::None).unwrap();
        w.add("packed", &data, Compression::Deflate).unwrap();
        w.add("tiny", b"ab", Compression::None).unwrap();
        w.finish().unwrap();
        let r = Reader::open(&path).unwrap();
        for name in ["plain", "packed"] {
            assert_eq!(r.extract_range(name, 0, 10_000).unwrap(), data, "{name}: whole");
            assert_eq!(r.extract_range(name, 100, 32).unwrap(), data[100..132], "{name}: mid");
            assert_eq!(
                r.extract_range(name, 9_990, 100).unwrap(),
                data[9_990..],
                "{name}: clamped tail"
            );
            assert!(r.extract_range(name, 20_000, 8).unwrap().is_empty(), "{name}: past EOF");
            assert!(r.extract_range(name, 5, 0).unwrap().is_empty(), "{name}: zero len");
        }
        assert_eq!(r.extract_range("tiny", 1, 10).unwrap(), b"b");
        assert!(r.extract_range("ghost", 0, 1).is_err());
    }

    #[test]
    fn open_indexed_range_mounts_index_over_partial_file() {
        use crate::cio::local::{create_sparse, read_range, write_range_at};
        let dir = tmpdir("partial");
        let full = dir.join("full.cioar");
        let data: Vec<u8> = (0..50_000u32).map(|i| (i % 251) as u8).collect();
        let mut w = Writer::create(&full).unwrap();
        w.add("a", &data, Compression::None).unwrap();
        w.add("b", &data, Compression::None).unwrap();
        w.finish().unwrap();
        let len = std::fs::metadata(&full).unwrap().len();

        // A sparse twin holding no bytes yet; the callback copies each
        // requested extent over from the full archive — exactly what the
        // partial-fill engine does with chunks.
        let sparse = dir.join("sparse.cioar");
        create_sparse(&sparse, len).unwrap();
        let mut asked: Vec<(u64, u64)> = Vec::new();
        let r = Reader::open_indexed_range(&sparse, &mut |off, n| {
            asked.push((off, n));
            let bytes = read_range(&full, off, n as usize)?;
            write_range_at(&sparse, off, &bytes)
        })
        .unwrap();
        // Exactly two extents were materialized: the 16-byte trailer,
        // then the index region — no member bytes.
        assert_eq!(asked.len(), 2, "{asked:?}");
        assert_eq!(asked[0], (len - 16, 16));
        assert_eq!(asked[1].0 + asked[1].1, len - 16, "index region ends at the trailer");
        let members_end: u64 = r.entries().iter().map(|e| e.stored_end()).max().unwrap();
        let sums_end = r.entry(SUMS_MEMBER).expect("checksum member").stored_end();
        assert_eq!(asked[1].0, sums_end, "index region starts after the sums member");
        assert!(sums_end > members_end, "sums member sits after the visible members");

        // Materialize just member b's extent and read records out of it;
        // member a's bytes never move.
        let e = r.entry("b").unwrap().clone();
        let span = read_range(&full, e.offset, (e.stored_end() - e.offset) as usize).unwrap();
        write_range_at(&sparse, e.offset, &span).unwrap();
        assert_eq!(r.extract_range("b", 100, 64).unwrap(), data[100..164]);
        assert_eq!(r.extract("b").unwrap(), data, "full member extract CRC-checks");
        let a = r.entry("a").unwrap();
        let hole = read_range(&sparse, a.data_offset(), 64).unwrap();
        assert_eq!(hole, vec![0u8; 64], "member a stays a hole in the sparse file");
    }

    #[test]
    fn entry_extent_helpers_bound_the_member_bytes() {
        let dir = tmpdir("extent-helpers");
        let path = dir.join("x.cioar");
        let mut w = Writer::create(&path).unwrap();
        w.add("m0", &vec![1u8; 100], Compression::None).unwrap();
        w.add("m1", &vec![2u8; 100], Compression::None).unwrap();
        w.finish().unwrap();
        let r = Reader::open(&path).unwrap();
        let (e0, e1) = (&r.entries()[0], &r.entries()[1]);
        assert_eq!(e0.data_offset() - e0.offset, e1.data_offset() - e1.offset);
        assert_eq!(e0.stored_end(), e1.offset, "members are packed back to back");
        assert_eq!(e0.stored_end() - e0.data_offset(), e0.stored_len);
    }

    #[test]
    fn duplicate_names_rejected() {
        let dir = tmpdir("dup");
        let mut w = Writer::create(&dir.join("d.cioar")).unwrap();
        w.add("x", b"1", Compression::None).unwrap();
        assert!(w.add("x", b"2", Compression::None).is_err());
    }

    #[test]
    fn corruption_detected() {
        let dir = tmpdir("corrupt");
        let path = dir.join("c.cioar");
        let mut w = Writer::create(&path).unwrap();
        w.add("victim", &vec![7u8; 4096], Compression::None).unwrap();
        w.finish().unwrap();
        // Flip a data byte mid-member.
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = 200;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let r = Reader::open(&path).unwrap();
        let err = r.extract("victim").unwrap_err();
        assert!(err.to_string().contains("CRC mismatch"), "{err}");
    }

    #[test]
    fn hidden_sums_member_is_invisible_but_reachable() {
        let dir = tmpdir("sums");
        let path = dir.join("s.cioar");
        let members = sample_members(5);
        let mut w = Writer::create(&path).unwrap();
        for (name, data) in &members {
            w.add(name, data, Compression::None).unwrap();
        }
        // Public adds may not squat on the hidden prefix.
        let err = w.add(".cio-evil", b"x", Compression::None).unwrap_err();
        assert!(err.to_string().contains("hidden-member prefix"), "{err}");
        w.finish().unwrap();
        let r = Reader::open(&path).unwrap();
        assert_eq!(r.len(), 5, "hidden member not enumerated");
        assert!(r.entries().iter().all(|e| !e.name.starts_with(HIDDEN_PREFIX)));
        let mut seq = 0;
        read_sequential(&path, |_, _| seq += 1).unwrap();
        assert_eq!(seq, 5, "sequential scan skips the sums member");
        // ... but exact-name lookup reaches it, CRC-checked.
        let sums = r.chunk_sums().unwrap().expect("sums present");
        assert_eq!(sums.chunk, SUM_CHUNK);
        assert_eq!(sums.data_end, r.entry(SUMS_MEMBER).unwrap().offset);
        assert_eq!(sums.crcs.len() as u64, sums.data_end.div_ceil(SUM_CHUNK));
    }

    #[test]
    fn sums_member_round_trips_and_rejects_unknown_algorithm() {
        let sums = ChunkSums { chunk: SUM_CHUNK, data_end: 10_000, crcs: vec![1, 2, 3] };
        let encoded = sums.encode();
        assert_eq!(encoded[0], SUM_ALGO_CRC32, "algorithm id leads the table");
        assert_eq!(ChunkSums::parse(&encoded).unwrap(), sums);

        // A future algorithm's table is refused by id, not misread.
        let mut keyed = encoded.clone();
        keyed[0] = 7;
        let err = ChunkSums::parse(&keyed).unwrap_err();
        assert!(err.to_string().contains("unsupported checksum algorithm id 7"), "{err}");

        // Truncation before the id byte is a parse error, not a panic.
        assert!(ChunkSums::parse(&[]).is_err());
    }

    #[test]
    fn verify_archive_detects_member_region_bit_flip() {
        let dir = tmpdir("verify");
        let path = dir.join("v.cioar");
        let mut w = Writer::create(&path).unwrap();
        w.add("m", &vec![9u8; 20_000], Compression::None).unwrap();
        w.finish().unwrap();
        assert_eq!(verify_archive(&path).unwrap(), Verification::Verified);
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[5_000] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = verify_archive(&path).unwrap_err();
        assert!(err.to_string().contains("checksum mismatch"), "{err}");
    }

    #[test]
    fn verify_span_checks_only_fully_covered_chunks() {
        let dir = tmpdir("span");
        let path = dir.join("sp.cioar");
        let data: Vec<u8> = (0..3 * SUM_CHUNK as usize + 100).map(|i| (i % 250) as u8).collect();
        let mut w = Writer::create(&path).unwrap();
        w.add("m", &data, Compression::None).unwrap();
        w.finish().unwrap();
        let r = Reader::open(&path).unwrap();
        let sums = r.chunk_sums().unwrap().unwrap();
        let file = std::fs::read(&path).unwrap();
        // Whole file (incl. index/trailer tail beyond data_end) verifies.
        sums.verify_span(0, &file).unwrap();
        // A chunk-aligned interior span verifies on its own.
        let (lo, hi) = (SUM_CHUNK as usize, 3 * SUM_CHUNK as usize);
        sums.verify_span(lo as u64, &file[lo..hi]).unwrap();
        // A span covering through data_end verifies the short tail chunk.
        sums.verify_span(lo as u64, &file[lo..]).unwrap();
        // A partially-covering span checks nothing — no false alarms.
        sums.verify_span(lo as u64 + 1, &file[lo + 1..hi - 1]).unwrap();
        // A flipped byte inside a covered chunk is caught.
        let mut bad = file[lo..hi].to_vec();
        bad[10] ^= 0xFF;
        let err = sums.verify_span(lo as u64, &bad).unwrap_err();
        assert!(err.to_string().contains("checksum mismatch"), "{err}");
    }

    #[test]
    fn legacy_sink_archives_verify_unchecked() {
        // A generic-sink writer (no path) emits no sums member; readers
        // and the verifier treat it as a legacy archive.
        let dir = tmpdir("legacy");
        let path = dir.join("l.cioar");
        let f = std::fs::File::create(&path).unwrap();
        let mut w = Writer::new(std::io::BufWriter::new(f)).unwrap();
        w.add("m", b"old-format", Compression::None).unwrap();
        w.finish().unwrap();
        let r = Reader::open(&path).unwrap();
        assert!(r.chunk_sums().unwrap().is_none());
        assert_eq!(verify_archive(&path).unwrap(), Verification::Unchecked);
    }

    #[test]
    fn truncated_archive_rejected() {
        let dir = tmpdir("trunc");
        let path = dir.join("t.cioar");
        std::fs::write(&path, b"short").unwrap();
        assert!(Reader::open(&path).is_err());
    }

    #[test]
    fn empty_archive_roundtrips() {
        let dir = tmpdir("empty");
        let path = dir.join("e.cioar");
        let w = Writer::create(&path).unwrap();
        assert!(w.is_empty());
        w.finish().unwrap();
        let r = Reader::open(&path).unwrap();
        assert!(r.is_empty());
        assert_eq!(read_sequential(&path, |_, _| {}).unwrap(), 0);
    }

    #[test]
    fn add_path_reads_from_disk() {
        let dir = tmpdir("frompath");
        let member = dir.join("input.bin");
        std::fs::write(&member, b"file contents").unwrap();
        let path = dir.join("f.cioar");
        let mut w = Writer::create(&path).unwrap();
        w.add_path("input.bin", &member, Compression::None).unwrap();
        w.finish().unwrap();
        let r = Reader::open(&path).unwrap();
        assert_eq!(r.extract("input.bin").unwrap(), b"file contents");
    }

    #[test]
    fn streamed_add_path_spans_many_chunks() {
        // A member several times the chunk size must stream through the
        // pool, land with a correct back-patched header, and round-trip
        // under both compressions.
        let dir = tmpdir("stream");
        let big: Vec<u8> = (0..3 * CHUNK + 12345).map(|i| (i % 253) as u8).collect();
        let member = dir.join("big.bin");
        std::fs::write(&member, &big).unwrap();
        for (tag, compression) in [("none", Compression::None), ("z", Compression::Deflate)] {
            let path = dir.join(format!("big-{tag}.cioar"));
            let mut w = Writer::create(&path).unwrap();
            w.add_path("big.bin", &member, compression).unwrap();
            w.add("after", b"still fine", Compression::None).unwrap();
            let entries = w.finish().unwrap();
            assert_eq!(entries[0].raw_len, big.len() as u64, "{tag}");
            assert_eq!(entries[0].crc32, crc32fast::hash(&big), "{tag}");
            let r = Reader::open(&path).unwrap();
            assert_eq!(r.extract("big.bin").unwrap(), big, "{tag}");
            assert_eq!(r.extract("after").unwrap(), b"still fine", "{tag}");
            // The sequential scan must agree with the patched headers too.
            let mut names = Vec::new();
            read_sequential(&path, |n, _| names.push(n.to_string())).unwrap();
            assert_eq!(names, ["big.bin", "after"], "{tag}");
        }
    }

    #[test]
    fn zero_length_member_roundtrips() {
        let dir = tmpdir("zero");
        let path = dir.join("zero.cioar");
        let empty = dir.join("empty.bin");
        std::fs::write(&empty, b"").unwrap();
        let mut w = Writer::create(&path).unwrap();
        w.add_path("empty-z", &empty, Compression::Deflate).unwrap();
        w.add_path("empty-n", &empty, Compression::None).unwrap();
        w.finish().unwrap();
        let r = Reader::open(&path).unwrap();
        assert_eq!(r.extract("empty-z").unwrap(), b"");
        assert_eq!(r.extract("empty-n").unwrap(), b"");
        assert_eq!(read_sequential(&path, |_, _| {}).unwrap(), 2);
    }

    #[test]
    fn parallel_writer_matches_sequential_layout() {
        let dir = tmpdir("pw");
        let members = sample_members(40);
        let mut specs = Vec::new();
        for (name, data) in &members {
            let p = dir.join(name);
            std::fs::write(&p, data).unwrap();
            specs.push((name.clone(), p));
        }
        let seq_path = dir.join("seq.cioar");
        let mut w = Writer::create(&seq_path).unwrap();
        for (name, p) in &specs {
            w.add_path(name, p, Compression::Deflate).unwrap();
        }
        let seq_entries = w.finish().unwrap();

        let par_path = dir.join("par.cioar");
        let mut w = Writer::create(&par_path).unwrap();
        w.add_paths_parallel(&specs, Compression::Deflate, 4).unwrap();
        let par_entries = w.finish().unwrap();

        // Same member order, sizes, and checksums; identical bytes back.
        assert_eq!(seq_entries.len(), par_entries.len());
        for (a, b) in seq_entries.iter().zip(&par_entries) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.raw_len, b.raw_len);
            assert_eq!(a.crc32, b.crc32);
        }
        let r = Reader::open(&par_path).unwrap();
        for (name, data) in &members {
            assert_eq!(&r.extract(name).unwrap(), data, "{name}");
        }
        // Sequential scan order matches submission order.
        let mut order = Vec::new();
        read_sequential(&par_path, |n, _| order.push(n.to_string())).unwrap();
        let want: Vec<String> = members.iter().map(|(n, _)| n.clone()).collect();
        assert_eq!(order, want);
    }

    #[test]
    fn parallel_writer_surfaces_missing_file() {
        let dir = tmpdir("pw-err");
        let ok = dir.join("ok.bin");
        std::fs::write(&ok, b"fine").unwrap();
        let specs = vec![
            ("ok".to_string(), ok),
            ("ghost".to_string(), dir.join("does-not-exist.bin")),
        ];
        let mut w = Writer::create(&dir.join("e.cioar")).unwrap();
        let err = w.add_paths_parallel(&specs, Compression::None, 4).unwrap_err();
        assert!(err.to_string().contains("does-not-exist"), "{err}");
    }

    #[test]
    fn failed_stream_poisons_writer() {
        // A reader that dies mid-member leaves orphaned bytes in the
        // file; the writer must refuse further members and refuse to
        // finish, so no index is ever written over the partial member.
        struct FailingReader {
            fed: bool,
        }
        impl std::io::Read for FailingReader {
            fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
                if self.fed {
                    Err(std::io::Error::other("disk on fire"))
                } else {
                    self.fed = true;
                    buf[..7].copy_from_slice(b"partial");
                    Ok(7)
                }
            }
        }
        let dir = tmpdir("poison");
        let path = dir.join("poison.cioar");
        let mut w = Writer::create(&path).unwrap();
        w.add("ok", b"fine", Compression::None).unwrap();
        let err = w
            .add_reader("victim", &mut FailingReader { fed: false }, Compression::None)
            .unwrap_err();
        assert!(err.to_string().contains("disk on fire"), "{err}");
        let err = w.add("after", b"x", Compression::None).unwrap_err();
        assert!(err.to_string().contains("poisoned"), "{err}");
        let err = w.finish().unwrap_err();
        assert!(err.to_string().contains("poisoned"), "{err}");
        // The unfinished file must not parse as an archive.
        assert!(Reader::open(&path).is_err());
    }

    #[test]
    fn duplicate_name_error_does_not_poison() {
        let dir = tmpdir("dup-ok");
        let path = dir.join("d2.cioar");
        let mut w = Writer::create(&path).unwrap();
        w.add("x", b"1", Compression::None).unwrap();
        assert!(w.add("x", b"2", Compression::None).is_err());
        // The file is still consistent: keep writing and finish cleanly.
        w.add("y", b"3", Compression::None).unwrap();
        w.finish().unwrap();
        let r = Reader::open(&path).unwrap();
        assert_eq!(r.extract("y").unwrap(), b"3");
    }

    #[test]
    fn corrupt_index_extent_rejected_at_open() {
        let dir = tmpdir("extent");
        let path = dir.join("x.cioar");
        let mut w = Writer::create(&path).unwrap();
        w.add("m", &vec![1u8; 512], Compression::None).unwrap();
        w.finish().unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // The index entry's stored_len lives after the trailer-relative
        // layout: corrupt it by blasting the index region with 0xFF (but
        // keep the trailer intact) — open must fail, not allocate wildly.
        let index_offset = {
            let t = &bytes[bytes.len() - 16..];
            u64::from_le_bytes(t[0..8].try_into().unwrap()) as usize
        };
        let end = bytes.len() - 16;
        for b in &mut bytes[index_offset + 8..end] {
            *b = 0xFF;
        }
        std::fs::write(&path, &bytes).unwrap();
        assert!(Reader::open(&path).is_err());
    }
}
