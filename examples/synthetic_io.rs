//! Synthetic IO sweep: a compact interactive version of Figures 14-16.
//!
//! Sweeps output size for a chosen partition size and task length, and
//! prints efficiency + throughput + collector behaviour per point.
//!
//! Run: `cargo run --release --example synthetic_io -- --procs 4096 --dur 4`

use cio::config::ClusterConfig;
use cio::sim::cluster::IoMode;
use cio::util::cli::Args;
use cio::util::table::Table;
use cio::util::units::{fmt_bytes, fmt_bw, kib, mib};
use cio::workload::synthetic::SyntheticWorkload;

fn main() {
    let args = Args::parse(false);
    let procs: u32 = args.get_parse_or("procs", 4096);
    let dur: f64 = args.get_parse_or("dur", 4.0);
    let waves: u32 = args.get_parse_or("waves", 3);
    let cfg = ClusterConfig::bgp(procs);

    let mut t = Table::new(vec![
        "out size",
        "CIO eff %",
        "GPFS eff %",
        "CIO MB/s",
        "GPFS MB/s",
        "CIO archives",
        "spills",
    ])
    .title(format!("{procs} processors, {dur}s tasks, {waves} waves"));

    for size in [kib(1), kib(16), kib(128), mib(1), mib(4)] {
        let wl = SyntheticWorkload::waves(&cfg, waves, dur, size);
        let ideal = wl.run(&cfg, IoMode::RamOnly);
        let cio = wl.run(&cfg, IoMode::Cio);
        let gpfs = wl.run(&cfg, IoMode::Gpfs);
        t.row(vec![
            fmt_bytes(size),
            format!("{:.1}", cio.efficiency_vs(&ideal) * 100.0),
            format!("{:.1}", gpfs.efficiency_vs(&ideal) * 100.0),
            fmt_bw(cio.write_throughput(size)),
            fmt_bw(gpfs.write_throughput(size)),
            format!("{}", cio.collector.archives),
            format!("{}", cio.staging_spills),
        ]);
    }
    print!("{}", t.render());
    println!("Efficiency is relative to a RAM-only run of the identical workload (the paper's definition).");
}
