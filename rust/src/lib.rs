//! # cio — a collective IO model for loosely coupled petascale programming
//!
//! Reproduction of Zhang et al., *Design and Evaluation of a Collective IO
//! Model for Loosely Coupled Petascale Programming* (MTAGS 2008).
//!
//! Loosely coupled (many-task) applications exchange data between program
//! invocations as ordinary files. At petascale, tens of thousands of compute
//! nodes contending on one shared parallel file system (GPFS on the Blue
//! Gene/P in the paper) turn file creation, small writes, and same-directory
//! metadata traffic into the dominant cost. This crate implements the
//! paper's remedy — file-domain *collective IO*:
//!
//! * a three-tier storage hierarchy: **GFS** (global persistent), **IFS**
//!   (intermediate file systems striped over node RAM disks), **LFS**
//!   (per-node RAM disk) — see [`sim`] for the simulated cluster and
//!   [`cio::placement`] for the tiering policy;
//! * an **input distributor** that broadcasts read-many input data from GFS
//!   to the IFSs over a spanning tree ([`cio::distributor`]);
//! * an **output collector** that batches task outputs on LFS/IFS and
//!   asynchronously archives them to GFS in large sequential units governed
//!   by a `maxDelay / maxData / minFreeSpace` policy ([`cio::collector`]);
//! * randomly accessible (xar-like) **archives** so downstream workflow
//!   stages can re-read collected outputs in parallel ([`cio::archive`]);
//! * a Falkon-like **task dispatcher** ([`cio::dispatch`]) and multi-stage
//!   dataflow plumbing ([`cio::stage`]), executed on real bytes by the
//!   stage runner with §5.3 inter-stage IFS retention
//!   ([`cio::local_stage`]).
//!
//! The original testbed (a 163,840-processor BG/P, GPFS, the torus and
//! collective-tree networks) is replaced by a deterministic discrete-event
//! cluster simulator ([`sim`]) calibrated to the paper's published
//! parameters; the collective-IO machinery itself also runs against real
//! directories and threads ([`cio::local`]) so the archive/collector code
//! paths are exercised with real bytes in tests and examples.
//!
//! Task compute payloads (the DOCK6-like docking screen of §6.3) execute a
//! JAX/Pallas-authored scoring model ahead-of-time lowered to HLO and run
//! from Rust via PJRT ([`runtime`]); Python is never on the request path.
//!
//! See `examples/` for runnable end-to-end drivers and `rust/benches/` for
//! the per-figure reproduction harnesses (Figures 11–17 of the paper).

pub mod cio;
pub mod config;
pub mod metrics;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workload;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Version string reported by the CLI and the bench harnesses.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
