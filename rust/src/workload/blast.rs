//! BLAST-like workload (§7: "applications (such as BLAST runs on large
//! databases) that will benefit greatly from striped IFS capabilities").
//!
//! Shape: a multi-GB sequence database is read by *every* task (the
//! read-many pattern at its most extreme), tasks are short relative to
//! their input volume, outputs are small hit lists. The database exceeds
//! a single LFS, so the placement policy sends it to replicated IFSs —
//! and the stripe degree determines whether the IFS can feed the readers.
//!
//! This module sweeps the stripe degree and reports per-stage times, the
//! `ablation_blast` bench prints the curve.

use crate::cio::distributor::TreeShape;
use crate::cio::local_stage::StageInput;
use crate::cio::placement::{Dataset, PlacementPolicy, Tier};
use crate::cio::stage::CacheOutcome;
use crate::config::ClusterConfig;
use crate::sim::cluster::{IoMode, SimCluster, TaskSpec};
use crate::util::units::{gib, kib};
use anyhow::Result;

/// BLAST-like workload parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct BlastWorkload {
    /// Database size (must exceed one LFS to exercise striping).
    pub db_bytes: u64,
    /// Number of query tasks.
    pub tasks: u64,
    /// Compute seconds per task (scan + align, after IO).
    pub dur_s: f64,
    /// Fraction of the database each task actually reads (index-guided
    /// scans touch a slice, not the whole DB).
    pub read_fraction: f64,
    /// Output (hit list) bytes per task.
    pub out_bytes: u64,
}

impl Default for BlastWorkload {
    fn default() -> Self {
        BlastWorkload {
            db_bytes: gib(8),
            tasks: 4096,
            dur_s: 30.0,
            read_fraction: 0.02,
            out_bytes: kib(64),
        }
    }
}

/// Fixed-size record layout inside an archived member — the real-bytes
/// half of the BLAST story. An index-guided scan touches a *slice* of
/// the database, not the whole member, so stage 2 should read records
/// out of retention ([`StageInput::read_member_range`] →
/// [`crate::cio::archive::Reader::extract_range`]) instead of extracting
/// whole members: the read volume drops from member size to
/// `records × record_bytes` while the three-tier hit/neighbor/miss
/// resolve stays identical.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecordFormat {
    /// Bytes per record (e.g. one sequence block or one ligand pose).
    pub record_bytes: usize,
}

impl RecordFormat {
    /// Byte range of record `idx` within a member.
    pub fn range(&self, idx: u64) -> (u64, usize) {
        (idx * self.record_bytes as u64, self.record_bytes)
    }

    /// Whole records in a member of `member_bytes` (a ragged tail is not
    /// a record).
    pub fn records_in(&self, member_bytes: u64) -> u64 {
        member_bytes / self.record_bytes as u64
    }

    /// Read record `idx` of `member` from retention. Errors when the
    /// member ends before the record does (a short read is corruption or
    /// an out-of-range index, never silently padded).
    pub fn read_record(
        &self,
        input: &StageInput<'_>,
        member: &str,
        idx: u64,
    ) -> Result<(Vec<u8>, CacheOutcome)> {
        let (offset, len) = self.range(idx);
        let (bytes, outcome) = input.read_member_range(member, offset, len)?;
        anyhow::ensure!(
            bytes.len() == len,
            "record {idx} of member {member:?} is truncated ({} of {len} bytes)",
            bytes.len()
        );
        Ok((bytes, outcome))
    }

    /// Read `count` consecutive records starting at `first` as one
    /// contiguous range read (one resolve, one extent — how a scan reads
    /// its slice of the database).
    pub fn read_records(
        &self,
        input: &StageInput<'_>,
        member: &str,
        first: u64,
        count: u64,
    ) -> Result<(Vec<u8>, CacheOutcome)> {
        let (offset, _) = self.range(first);
        let len = (count as usize) * self.record_bytes;
        let (bytes, outcome) = input.read_member_range(member, offset, len)?;
        anyhow::ensure!(
            bytes.len() == len,
            "records {first}..{} of member {member:?} truncated ({} of {len} bytes)",
            first + count,
            bytes.len()
        );
        Ok((bytes, outcome))
    }
}

/// Result of one BLAST run.
#[derive(Debug, Clone)]
pub struct BlastResult {
    /// Stripe degree used.
    pub stripe: u32,
    /// Where the placement policy put the database.
    pub db_tier: Tier,
    /// Seconds to distribute the database to the IFSs (CIO only).
    pub distribution_s: f64,
    /// Query-phase makespan, CIO.
    pub cio_s: f64,
    /// Query-phase makespan, GPFS baseline (reads hit the GFS).
    pub gpfs_s: f64,
}

impl BlastResult {
    /// End-to-end speedup including the distribution cost.
    pub fn speedup(&self) -> f64 {
        self.gpfs_s / (self.distribution_s + self.cio_s)
    }
}

impl BlastWorkload {
    /// Per-task input bytes.
    pub fn in_bytes(&self) -> u64 {
        (self.db_bytes as f64 * self.read_fraction) as u64
    }

    /// How many records of `fmt` one task's index-guided scan touches —
    /// the record-granular equivalent of [`BlastWorkload::in_bytes`]
    /// (at least one: a task that reads nothing is not a query).
    pub fn records_per_task(&self, fmt: &RecordFormat) -> u64 {
        (self.in_bytes() / fmt.record_bytes as u64).max(1)
    }

    /// Run with the given stripe degree.
    pub fn run(&self, cfg: &ClusterConfig, stripe: u32) -> BlastResult {
        let cfg = cfg.clone().with_stripe(stripe);
        // Placement: the DB is read by every task.
        let policy = PlacementPolicy::from_config(&cfg);
        let db = Dataset { name: "blast.db".into(), bytes: self.db_bytes, readers: cfg.procs };
        let db_tier = policy.decide(&db);

        // Distribution: broadcast to the IFS groups over the tree.
        let mut sim = SimCluster::new(&cfg);
        let (distribution_s, _) = sim.distribute_tree(
            cfg.ifs_groups().max(2),
            self.db_bytes,
            TreeShape::Binomial,
        );

        let spec = TaskSpec {
            dur: crate::sim::cluster::DurationModel::Fixed(self.dur_s),
            out_bytes: self.out_bytes,
            in_bytes: self.in_bytes(),
            in_from_ifs: false, // overridden by run_mtc_ifs_input below
        };
        // CIO: reads come from the striped IFS (modelled by the per-group
        // serve resource inside run_mtc_spec's staged-input path — which
        // uses the LFS path; for BLAST the slice is re-read from IFS, so
        // point the input at the IFS serve bandwidth instead).
        let mut cio = SimCluster::new(&cfg);
        let cio_r = cio.run_mtc_ifs_input(self.tasks, &spec, IoMode::Cio);
        let mut gpfs = SimCluster::new(&cfg);
        let gpfs_r = gpfs.run_mtc_spec(self.tasks, &spec, IoMode::Gpfs);
        BlastResult {
            stripe,
            db_tier,
            distribution_s,
            cio_s: cio_r.makespan_tasks_s,
            gpfs_s: gpfs_r.makespan_tasks_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_format_geometry() {
        let fmt = RecordFormat { record_bytes: 4096 };
        assert_eq!(fmt.range(0), (0, 4096));
        assert_eq!(fmt.range(7), (7 * 4096, 4096));
        assert_eq!(fmt.records_in(4096 * 10), 10);
        assert_eq!(fmt.records_in(4096 * 10 + 100), 10, "ragged tail is not a record");
        assert_eq!(fmt.records_in(100), 0);
    }

    #[test]
    fn records_per_task_tracks_read_fraction() {
        let wl = BlastWorkload { db_bytes: gib(8), read_fraction: 0.02, ..Default::default() };
        let fmt = RecordFormat { record_bytes: kib(64) as usize };
        // 2% of 8 GiB = ~160 MiB => ~2560 64-KiB records.
        let records = wl.records_per_task(&fmt);
        assert!((2500..2700).contains(&records), "{records}");
        // Record reads move ~50x less than whole-member (full-slice) ones
        // would if members held the whole per-task slice... the floor is 1.
        let tiny = BlastWorkload { db_bytes: kib(64), read_fraction: 0.0001, ..wl };
        assert_eq!(tiny.records_per_task(&fmt), 1);
    }

    #[test]
    fn db_goes_to_replicated_ifs() {
        let cfg = ClusterConfig::bgp(4096).with_stripe(8);
        let wl = BlastWorkload::default();
        let r = wl.run(&cfg, 8);
        assert_eq!(r.db_tier, Tier::IfsReplicated, "8 GB read-many DB belongs on IFSs");
    }

    #[test]
    fn striping_helps_read_heavy_queries() {
        let cfg = ClusterConfig::bgp(1024);
        let wl = BlastWorkload { tasks: 1024, ..Default::default() };
        let r1 = wl.run(&cfg, 1);
        let r16 = wl.run(&cfg, 16);
        assert!(
            r16.cio_s < r1.cio_s * 0.7,
            "16-way striping should cut the query phase: {} vs {}",
            r16.cio_s,
            r1.cio_s
        );
    }

    #[test]
    fn cio_beats_gpfs_baseline_at_scale() {
        // The striped-IFS win is a *scale* effect: at 4096 processors the
        // 16 IFS groups aggregate ~11 GB/s of serving bandwidth against
        // GPFS's fixed 2.4 GB/s, which amortizes the one-time broadcast.
        // (At small scale GFS aggregate ≈ IFS aggregate and the broadcast
        // cost makes CIO *lose* — the crossover the ablation bench plots.)
        // 8 query waves amortize the one-time 8 GB broadcast.
        let cfg = ClusterConfig::bgp(4096);
        let wl = BlastWorkload { tasks: 32_768, ..Default::default() };
        let r = wl.run(&cfg, 16);
        assert!(r.speedup() > 1.8, "speedup {}", r.speedup());
    }
}
