//! Synthetic MTC workloads (§6.2): fixed-length tasks producing
//! fixed-size outputs — the sweep axes of Figures 14/15/16.

use crate::sim::cluster::{IoMode, RunReport, SimCluster};
use crate::config::ClusterConfig;

/// A synthetic workload specification.
#[derive(Debug, Clone, PartialEq)]
pub struct SyntheticWorkload {
    /// Number of tasks.
    pub tasks: u64,
    /// Per-task compute duration (s). The paper uses 4 s and 32 s.
    pub dur_s: f64,
    /// Per-task output size (bytes). The paper sweeps 1 KB – 1 MB.
    pub out_bytes: u64,
}

impl SyntheticWorkload {
    /// New workload spec.
    pub fn new(tasks: u64, dur_s: f64, out_bytes: u64) -> Self {
        assert!(tasks > 0 && dur_s > 0.0);
        SyntheticWorkload { tasks, dur_s, out_bytes }
    }

    /// The paper-style sizing: `waves` full waves across the partition.
    pub fn waves(cfg: &ClusterConfig, waves: u32, dur_s: f64, out_bytes: u64) -> Self {
        Self::new(cfg.procs as u64 * waves as u64, dur_s, out_bytes)
    }

    /// Run on a fresh simulated partition.
    pub fn run(&self, cfg: &ClusterConfig, mode: IoMode) -> RunReport {
        let mut cluster = SimCluster::new(cfg);
        cluster.run_mtc(self.tasks, self.dur_s, self.out_bytes, mode)
    }

    /// Run mode + the RamOnly ideal and return (report, efficiency).
    pub fn run_with_efficiency(&self, cfg: &ClusterConfig, mode: IoMode) -> (RunReport, f64) {
        let ideal = self.run(cfg, IoMode::RamOnly);
        let report = self.run(cfg, mode);
        let eff = report.efficiency_vs(&ideal);
        (report, eff)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::kib;

    #[test]
    fn waves_scale_with_procs() {
        let cfg = ClusterConfig::bgp(256);
        let w = SyntheticWorkload::waves(&cfg, 3, 4.0, kib(1));
        assert_eq!(w.tasks, 768);
    }

    #[test]
    fn efficiency_helper_consistent() {
        let cfg = ClusterConfig::bgp(256);
        let w = SyntheticWorkload::waves(&cfg, 2, 4.0, kib(64));
        let (report, eff) = w.run_with_efficiency(&cfg, IoMode::Cio);
        assert_eq!(report.tasks, w.tasks);
        assert!(eff > 0.5 && eff <= 1.0, "eff {eff}");
    }
}
