//! Figure 11: IFS read performance over the torus, varying the file size
//! and the LFS:IFS (client:server) ratio from 64:1 to 512:1.
//!
//! Paper anchors: best aggregate 162 MB/s at 100 MB files / 256:1;
//! per-node 2.3 MB/s at 64:1 vs 0.6 MB/s at 256:1; the 512:1 / 100 MB
//! configuration FAILS with chirp-server memory exhaustion.
//!
//! Regenerate: `cargo bench --bench fig11`

#[path = "common/mod.rs"]
mod common;

use cio::config::ClusterConfig;
use cio::metrics::Report;
use cio::sim::cluster::SimCluster;
use cio::util::table::{num, Table};
use cio::util::units::{fmt_bytes, kib, mib};

fn main() {
    let args = common::args();
    let ratios: &[u32] = &[64, 128, 256, 512];
    let sizes: &[u64] = if common::fast() {
        &[mib(1), mib(100)]
    } else {
        &[kib(100), mib(1), mib(10), mib(100)]
    };

    let mut table = Table::new(vec!["file size", "ratio", "aggregate MB/s", "per-node MB/s"])
        .title("Figure 11: IFS (chirp) read bandwidth over torus");
    let mut report = Report::new("Figure 11 anchors");
    let mut fail_seen = false;

    for &size in sizes {
        for &ratio in ratios {
            // A partition whose IFS group is exactly `ratio` clients.
            let cfg = ClusterConfig::bgp(ratio * 4).with_ifs_ratio(ratio);
            let mut cluster = SimCluster::new(&cfg);
            match cluster.chirp_read_benchmark(ratio, size) {
                Ok(agg) => {
                    let agg_mb = agg / mib(1) as f64;
                    let per_node = agg_mb / ratio as f64;
                    table.row(vec![
                        fmt_bytes(size),
                        format!("{ratio}:1"),
                        num(agg_mb),
                        format!("{per_node:.2}"),
                    ]);
                    if size == mib(100) && ratio == 256 {
                        report.push("aggregate @100MB,256:1", 162.0, agg_mb, "MB/s");
                        report.push("per-node @100MB,256:1", 0.6, per_node, "MB/s");
                    }
                    if size == mib(100) && ratio == 64 {
                        report.push("per-node @100MB,64:1", 2.3, per_node, "MB/s");
                    }
                }
                Err(e) => {
                    table.row(vec![
                        fmt_bytes(size),
                        format!("{ratio}:1"),
                        "FAILED".to_string(),
                        format!("{e}").chars().take(28).collect(),
                    ]);
                    if size == mib(100) && ratio == 512 {
                        fail_seen = true;
                    }
                }
            }
        }
    }
    print!("{}", table.render());
    common::maybe_write_csv(&args, &table.to_csv());
    println!(
        "512:1 @ 100MB memory-exhaustion failure reproduced: {}",
        if fail_seen { "YES (paper: benchmarks failed due to memory exhaustion)" } else { "NO" }
    );
    common::footer(&report);
    assert!(fail_seen || common::fast(), "the paper's OOM failure must reproduce");
}
