//! Figure 13: collective input distribution via spanning tree over the
//! torus vs naive per-node GPFS reads, on 256–4096 processors.
//!
//! Paper anchors: naive GPFS staging tops out at its 2.4 GB/s rated peak
//! (2.4 MB/s per node at 4K processors); the spanning tree achieves an
//! *equivalent* 12.5 GB/s at 4K processors (equivalent = n*size/time, the
//! paper's deliberately conservative comparison).
//!
//! Regenerate: `cargo bench --bench fig13`

#[path = "common/mod.rs"]
mod common;

use cio::cio::distributor::TreeShape;
use cio::config::ClusterConfig;
use cio::metrics::Report;
use cio::sim::cluster::SimCluster;
use cio::util::table::{num, Table};
use cio::util::units::mib;

fn main() {
    let args = common::args();
    let procs_list: &[u32] =
        if common::fast() { &[256, 4096] } else { &[256, 512, 1024, 2048, 4096] };
    let size = mib(100);

    let mut table = Table::new(vec![
        "procs",
        "nodes",
        "GPFS time (s)",
        "GPFS GB/s",
        "tree time (s)",
        "tree equiv GB/s",
        "speedup",
    ])
    .title("Figure 13: input distribution, 100 MB to all nodes");
    let mut report = Report::new("Figure 13 anchors");

    for &procs in procs_list {
        let cfg = ClusterConfig::bgp(procs);
        let nodes = cfg.nodes();
        let mut naive = SimCluster::new(&cfg);
        let (tn, aggn) = naive.distribute_naive(nodes, size);
        let mut tree = SimCluster::new(&cfg);
        let (tt, aggt) = tree.distribute_tree(nodes, size, TreeShape::Binomial);
        let gn = aggn / mib(1024) as f64;
        let gt = aggt / mib(1024) as f64;
        table.row(vec![
            format!("{procs}"),
            format!("{nodes}"),
            num(tn),
            num(gn),
            num(tt),
            num(gt),
            format!("{:.1}x", tn / tt),
        ]);
        if procs == 4096 {
            report.push("GPFS aggregate @4K procs", 2.4, gn, "GB/s");
            report.push("tree equivalent @4K procs", 12.5, gt, "GB/s");
            report.push("per-node GPFS @4K", 2.4, aggn / nodes as f64 / mib(1) as f64, "MB/s");
        }
    }
    print!("{}", table.render());
    common::maybe_write_csv(&args, &table.to_csv());
    common::footer(&report);
}
