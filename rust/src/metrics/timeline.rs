//! Time-series sampling of simulation state (utilization timelines).
//!
//! The figure benches report end-of-run aggregates; for debugging and for
//! the `cio run --trace` CLI flag we also want *when* things happened:
//! GFS bytes landed, staging occupancy, tasks completed. [`Timeline`]
//! collects (t, value) points per named series and renders them as CSV or
//! a coarse ASCII sparkline.

use crate::util::units::SimTime;
use std::collections::BTreeMap;

/// A set of named time series.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    series: BTreeMap<String, Vec<(SimTime, f64)>>,
}

impl Timeline {
    /// Empty timeline.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one observation.
    pub fn push(&mut self, series: &str, t: SimTime, value: f64) {
        let s = self.series.entry(series.to_string()).or_default();
        debug_assert!(s.last().map(|&(lt, _)| lt <= t).unwrap_or(true), "time went backwards");
        s.push((t, value));
    }

    /// Number of series.
    pub fn len(&self) -> usize {
        self.series.len()
    }

    /// True when nothing was recorded.
    pub fn is_empty(&self) -> bool {
        self.series.is_empty()
    }

    /// Points of one series.
    pub fn points(&self, series: &str) -> Option<&[(SimTime, f64)]> {
        self.series.get(series).map(Vec::as_slice)
    }

    /// Resample a series onto `buckets` uniform time bins (last value
    /// wins per bin; empty bins carry the previous value forward).
    pub fn resample(&self, series: &str, buckets: usize) -> Option<Vec<f64>> {
        let pts = self.series.get(series)?;
        if pts.is_empty() || buckets == 0 {
            return Some(vec![]);
        }
        let end = pts.last().unwrap().0;
        let span = end.0.max(1) as f64;
        let mut out = vec![f64::NAN; buckets];
        for &(t, v) in pts {
            let idx = ((t.0 as f64 / span) * (buckets - 1) as f64).round() as usize;
            out[idx.min(buckets - 1)] = v;
        }
        // Forward-fill.
        let mut last = pts[0].1;
        for slot in out.iter_mut() {
            if slot.is_nan() {
                *slot = last;
            } else {
                last = *slot;
            }
        }
        Some(out)
    }

    /// ASCII sparkline of a series (resampled to `width` columns).
    pub fn sparkline(&self, series: &str, width: usize) -> Option<String> {
        const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let vals = self.resample(series, width)?;
        if vals.is_empty() {
            return Some(String::new());
        }
        let (min, max) = vals.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &v| {
            (lo.min(v), hi.max(v))
        });
        let range = (max - min).max(1e-12);
        Some(
            vals.iter()
                .map(|&v| BARS[(((v - min) / range) * 7.0).round() as usize])
                .collect(),
        )
    }

    /// CSV export: `series,t_seconds,value` rows.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("series,t_seconds,value\n");
        for (name, pts) in &self.series {
            for &(t, v) in pts {
                out.push_str(&format!("{name},{},{v}\n", t.as_secs_f64()));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: u64) -> SimTime {
        SimTime::from_secs(s)
    }

    #[test]
    fn push_and_read_back() {
        let mut tl = Timeline::new();
        assert!(tl.is_empty());
        tl.push("gfs_bytes", t(1), 100.0);
        tl.push("gfs_bytes", t(2), 250.0);
        tl.push("staging", t(1), 10.0);
        assert_eq!(tl.len(), 2);
        assert_eq!(tl.points("gfs_bytes").unwrap().len(), 2);
        assert!(tl.points("missing").is_none());
    }

    #[test]
    fn resample_forward_fills() {
        let mut tl = Timeline::new();
        tl.push("x", t(0), 1.0);
        tl.push("x", t(10), 5.0);
        let r = tl.resample("x", 11).unwrap();
        assert_eq!(r.len(), 11);
        assert_eq!(r[0], 1.0);
        assert_eq!(r[10], 5.0);
        // Middle bins carry 1.0 forward.
        assert_eq!(r[5], 1.0);
    }

    #[test]
    fn sparkline_shape() {
        let mut tl = Timeline::new();
        for i in 0..20u64 {
            tl.push("ramp", t(i), i as f64);
        }
        let s = tl.sparkline("ramp", 10).unwrap();
        assert_eq!(s.chars().count(), 10);
        let chars: Vec<char> = s.chars().collect();
        assert_eq!(*chars.first().unwrap(), '▁');
        assert_eq!(*chars.last().unwrap(), '█');
    }

    #[test]
    fn csv_export() {
        let mut tl = Timeline::new();
        tl.push("a", t(1), 2.5);
        let csv = tl.to_csv();
        assert!(csv.starts_with("series,t_seconds,value\n"));
        assert!(csv.contains("a,1,2.5"));
    }

    #[test]
    fn constant_series_sparkline_is_flat() {
        let mut tl = Timeline::new();
        tl.push("c", t(0), 4.0);
        tl.push("c", t(5), 4.0);
        let s = tl.sparkline("c", 5).unwrap();
        assert!(s.chars().all(|c| c == '▁'), "{s}");
    }
}
