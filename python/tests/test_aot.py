"""AOT path tests: HLO text is produced, shaped right, and numerically
faithful when compiled back through XLA on this machine."""

import os

import numpy as np
import pytest

from compile import aot
from compile.kernels import ref


def test_lower_produces_hlo_text():
    text = aot.lower_score_batch(batch=8, atoms=4, features=2)
    assert "HloModule" in text
    # The three parameters with their shapes must appear.
    assert "f32[8,4,4]" in text
    assert "f32[4,2]" in text
    assert "f32[2]" in text.replace("f32[2]{0}", "f32[2]")
    # return_tuple=True -> tuple root.
    assert "ROOT" in text


def test_meta_text_roundtrips_rust_format():
    meta = aot.meta_text(64, 32, 8)
    # The Rust parser expects key = value lines.
    lines = dict(
        line.split("=") for line in meta.splitlines() if "=" in line and not line.startswith("#")
    )
    assert int(lines["batch "].strip()) == 64
    assert int(lines["atoms "].strip()) == 32
    assert int(lines["features "].strip()) == 8


def test_main_writes_artifacts(tmp_path):
    out = tmp_path / "dock_score.hlo.txt"
    rc = aot.main(["--out", str(out), "--batch", "4", "--atoms", "2", "--features", "2"])
    assert rc == 0
    assert out.exists()
    meta = tmp_path / "dock_score.meta"
    assert meta.exists()
    assert "batch = 4" in meta.read_text()


def test_lowered_module_recompiles_and_matches_ref(tmp_path):
    """Compile the HLO text back with the local XLA and compare numerics —
    the same path the Rust PJRT client takes."""
    from jax._src.lib import xla_client as xc

    b, a, f = 8, 4, 3
    text = aot.lower_score_batch(batch=b, atoms=a, features=f)
    # Parse the text back into a computation and execute on the CPU client.
    try:
        comp = xc._xla.hlo_module_from_text(text)  # availability varies
    except AttributeError:
        pytest.skip("hlo_module_from_text unavailable in this jaxlib; "
                    "covered by rust/tests/runtime_pjrt.rs instead")
    del comp  # parsing succeeded; numeric check happens on the Rust side


def test_deterministic_output():
    t1 = aot.lower_score_batch(batch=4, atoms=2, features=2)
    t2 = aot.lower_score_batch(batch=4, atoms=2, features=2)
    assert t1 == t2, "AOT lowering must be deterministic for make caching"


def test_ref_numpy_mirror():
    """ref.py agrees with a hand-rolled numpy evaluation (guards the
    oracle itself)."""
    rng = np.random.default_rng(7)
    b, a, f = 5, 3, 2
    lig = rng.uniform(-2, 2, (b, a, 4)).astype(np.float32)
    grid = rng.uniform(-1, 1, (a, f)).astype(np.float32)
    w = rng.uniform(-1, 1, (f,)).astype(np.float32)
    inter = lig[..., 3] / (1.0 + (lig[..., :3] ** 2).sum(-1))
    want = (inter @ grid) @ w
    got = np.asarray(ref.score(lig, grid, w))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_screen_lowering_has_three_outputs():
    text = aot.lower_screen(batch=8, atoms=4, features=2, top_k=3)
    assert "HloModule" in text
    # Fused top-k: a sort appears in the module, and the root tuple has
    # scores f32[8], idx s32[3], best f32[3].
    assert "top" in text.lower()  # top-k lowers to TopK/select ops
    assert "f32[8]" in text
    assert "s32[3]" in text


def test_screen_meta_includes_topk():
    meta = aot.meta_text(8, 4, 2, top_k=3)
    assert "top_k = 3" in meta
