//! End-to-end driver: the full three-layer stack on a real (small)
//! docking screen.
//!
//! All layers compose here:
//!   L1/L2 — the Pallas docking kernel inside the JAX model, AOT-lowered
//!           to `artifacts/dock_score.hlo.txt` by `make artifacts`;
//!   runtime — Rust loads the HLO via PJRT and scores every compound
//!           batch (Python is never invoked);
//!   L3   — the collective-IO machinery moves real bytes: the receptor
//!           grid is broadcast to the IFS replicas over a spanning tree,
//!           per-batch ligand files are staged, task outputs are committed
//!           LFS→IFS staging, the threaded collector archives them into
//!           indexed archives on the GFS directory, and stage 2 re-reads
//!           the archives with parallel random access to select the best
//!           compounds.
//!
//! The PJRT executable lives on a dedicated scorer thread (the xla crate's
//! client is not Send) fed through a request channel — the same
//! leader/worker shape the simulated dispatcher models.
//!
//! A baseline pass writes one file per task straight into a single GFS
//! directory (the paper's GPFS pattern) for the headline comparison:
//! file-count reduction and wall-clock. Results are recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//! Run: `make artifacts && cargo run --release --example dock_screening`
//! (env: DOCK_TASKS=256 DOCK_NODES=16 to rescale)

use cio::cio::archive::{Compression, Reader};
use cio::cio::collector::Policy;
use cio::cio::distributor::TreeShape;
use cio::cio::local::{distribute_to_ifs, LocalCollector, LocalLayout};
use cio::runtime::{artifacts_dir, score_reference, ArtifactMeta, ScoreModel};
use cio::util::rng::Rng;
use cio::util::table::Table;
use cio::util::units::SimTime;
use std::io::Write as _;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Mutex;
use std::time::Instant;

/// A scoring request: ligand batch in, scores out through the reply
/// channel. The scorer thread owns the (non-Send) PJRT executable.
struct ScoreRequest {
    ligands: Vec<f32>,
    grid: Vec<f32>,
    weights: Vec<f32>,
    reply: mpsc::Sender<anyhow::Result<Vec<f32>>>,
}

fn spawn_scorer() -> (mpsc::Sender<ScoreRequest>, std::thread::JoinHandle<anyhow::Result<()>>) {
    let (tx, rx) = mpsc::channel::<ScoreRequest>();
    let handle = std::thread::spawn(move || -> anyhow::Result<()> {
        let model = ScoreModel::load_default()?; // created on this thread
        for req in rx {
            let result = model.score_batch(&req.ligands, &req.grid, &req.weights);
            let _ = req.reply.send(result);
        }
        Ok(())
    });
    (tx, handle)
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    cio::util::logging::init();
    let tasks = env_usize("DOCK_TASKS", 192);
    let nodes = env_usize("DOCK_NODES", 16) as u32;
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8);

    // Shape metadata (the artifact itself loads on the scorer thread).
    let meta = ArtifactMeta::load(&artifacts_dir().join("dock_score.meta"))
        .map_err(|e| anyhow::anyhow!("{e}\nrun `make artifacts` first"))?;
    println!("artifact shapes: batch={} atoms={} features={}", meta.batch, meta.atoms, meta.features);
    let (scorer, scorer_handle) = spawn_scorer();

    // --- Build the storage hierarchy and the compound library on "GFS".
    let root = std::env::temp_dir().join(format!("cio-dock-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let layout = LocalLayout::create(&root, nodes, 8)?;
    let mut rng = Rng::new(42);

    // Receptor grid + weights: the read-many dataset (broadcast).
    let grid: Vec<f32> =
        (0..meta.atoms * meta.features).map(|_| rng.f64_range(-1.0, 1.0) as f32).collect();
    let weights: Vec<f32> = (0..meta.features).map(|_| rng.f64_range(-1.0, 1.0) as f32).collect();
    write_f32s(&layout.gfs().join("receptor.grid"), &grid)?;
    write_f32s(&layout.gfs().join("receptor.weights"), &weights)?;

    // Ligand batches: read-few, one file per task.
    for t in 0..tasks {
        let lig: Vec<f32> = (0..meta.batch * meta.atoms * 4)
            .map(|_| rng.f64_range(-3.0, 3.0) as f32)
            .collect();
        write_f32s(&layout.gfs().join(format!("ligands-{t:04}.bin")), &lig)?;
    }

    // --- Input distribution: broadcast the read-many grid to every IFS
    // over the spanning tree (Chirp-replicate style).
    let copies = distribute_to_ifs(&layout, "receptor.grid", TreeShape::Binomial)?;
    distribute_to_ifs(&layout, "receptor.weights", TreeShape::Binomial)?;
    println!("broadcast receptor grid to {} IFS replicas ({copies} copies)", layout.ifs_groups());

    // --- CIO pass: score + commit + collector archives.
    let policy =
        Policy { max_delay: SimTime::from_secs(2), max_data: 8 * 1024, min_free_space: 0 };
    let collector = LocalCollector::start(&layout, policy, Compression::Deflate);
    let t0 = Instant::now();
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for w in 0..workers {
            let layout = &layout;
            let collector = &collector;
            let next = &next;
            let weights = &weights;
            let meta = &meta;
            let scorer = scorer.clone();
            scope.spawn(move || {
                loop {
                    let t = next.fetch_add(1, Ordering::Relaxed);
                    if t >= tasks {
                        return;
                    }
                    let node = (t % nodes as usize) as u32;
                    // Read staged inputs: grid from the node's IFS
                    // replica, ligands from GFS (read-few).
                    let g = layout.ifs_data(layout.group_of(node)).join("receptor.grid");
                    let grid_local = read_f32s(&g).expect("staged grid");
                    let lig = read_f32s(&layout.gfs().join(format!("ligands-{t:04}.bin")))
                        .expect("ligand batch");
                    // L1/L2 compute via the PJRT scorer thread.
                    let (reply_tx, reply_rx) = mpsc::channel();
                    scorer
                        .send(ScoreRequest {
                            ligands: lig.clone(),
                            grid: grid_local.clone(),
                            weights: weights.clone(),
                            reply: reply_tx,
                        })
                        .expect("scorer alive");
                    let scores = reply_rx.recv().expect("scorer reply").expect("pjrt");
                    // Spot-check against the pure-Rust oracle.
                    if w == 0 && t < 4 {
                        let want = score_reference(meta, &lig, &grid_local, weights);
                        for (a, b) in scores.iter().zip(&want) {
                            assert!((a - b).abs() < 1e-3 * b.abs().max(1.0), "{a} vs {b}");
                        }
                    }
                    // Write output to LFS, then commit LFS -> IFS staging
                    // (waking the group's collector via its condvar).
                    let name = format!("scores-{t:04}.bin");
                    write_f32s(&layout.lfs(node).join(&name), &scores).expect("lfs write");
                    collector.commit(layout, node, &name).expect("commit");
                }
            });
        }
    });
    let compute_elapsed = t0.elapsed();
    let stats = collector.finish()?;
    let cio_elapsed = t0.elapsed();
    assert_eq!(stats.files, tasks as u64, "all outputs archived");

    // --- Stage 2: parallel random-access re-read of the archives, select
    // the globally best pose.
    let t2 = Instant::now();
    let best = Mutex::new((f32::INFINITY, String::new()));
    let mut archives = Vec::new();
    for entry in std::fs::read_dir(layout.gfs())? {
        let p = entry?.path();
        if p.extension().is_some_and(|e| e == "cioar") {
            archives.push(p);
        }
    }
    let mut members_seen = 0usize;
    for a in &archives {
        let r = Reader::open(a)?;
        members_seen += r.len();
        r.extract_parallel(workers, |name, bytes| {
            let scores = bytes_to_f32s(bytes);
            let (min_idx, min_val) = scores
                .iter()
                .enumerate()
                .fold((0usize, f32::INFINITY), |acc, (i, &v)| if v < acc.1 { (i, v) } else { acc });
            let mut b = best.lock().unwrap();
            if min_val < b.0 {
                *b = (min_val, format!("{name}#pose{min_idx}"));
            }
        })?;
    }
    let stage2_elapsed = t2.elapsed();
    let best = best.into_inner().unwrap();
    assert_eq!(members_seen, tasks);

    // --- Baseline pass: per-task files straight into one GFS directory.
    let t3 = Instant::now();
    let base_dir = layout.gfs().join("baseline-outputs");
    std::fs::create_dir_all(&base_dir)?;
    for t in 0..tasks {
        let lig = read_f32s(&layout.gfs().join(format!("ligands-{t:04}.bin")))?;
        let (reply_tx, reply_rx) = mpsc::channel();
        scorer.send(ScoreRequest {
            ligands: lig,
            grid: grid.clone(),
            weights: weights.clone(),
            reply: reply_tx,
        })?;
        let scores = reply_rx.recv()??;
        write_f32s(&base_dir.join(format!("scores-{t:04}.bin")), &scores)?;
    }
    let baseline_elapsed = t3.elapsed();
    let baseline_files = std::fs::read_dir(&base_dir)?.count();
    drop(scorer);
    scorer_handle.join().expect("scorer thread")?;

    // --- Report.
    let mut t = Table::new(vec!["metric", "value"]).title(format!(
        "end-to-end dock screen: {} tasks x {} poses on {} virtual nodes ({} workers)",
        tasks, meta.batch, nodes, workers
    ));
    let total_poses = tasks * meta.batch;
    t.row(vec!["poses scored".into(), format!("{total_poses}")]);
    t.row(vec![
        "PJRT scoring throughput".into(),
        format!("{:.0} poses/s", total_poses as f64 / compute_elapsed.as_secs_f64()),
    ]);
    t.row(vec!["CIO wall-clock (score+collect)".into(), format!("{cio_elapsed:.2?}")]);
    t.row(vec!["stage-2 parallel re-read".into(), format!("{stage2_elapsed:.2?}")]);
    t.row(vec!["baseline wall-clock".into(), format!("{baseline_elapsed:.2?}")]);
    t.row(vec!["GFS files (CIO)".into(), format!("{} archives", archives.len())]);
    t.row(vec!["GFS files (baseline)".into(), format!("{baseline_files}")]);
    t.row(vec![
        "file-count reduction".into(),
        format!("{:.0}x", baseline_files as f64 / archives.len().max(1) as f64),
    ]);
    t.row(vec!["best pose".into(), format!("{} (score {:.4})", best.1, best.0)]);
    t.row(vec![
        "collector reasons [delay,data,free,shutdown]".into(),
        format!("{:?}", stats.reasons),
    ]);
    print!("{}", t.render());
    println!("(workspace: {})", root.display());
    Ok(())
}

fn write_f32s(path: &PathBuf, xs: &[f32]) -> anyhow::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for x in xs {
        f.write_all(&x.to_le_bytes())?;
    }
    f.flush()?;
    Ok(())
}

fn read_f32s(path: &PathBuf) -> anyhow::Result<Vec<f32>> {
    Ok(bytes_to_f32s(&std::fs::read(path)?))
}

fn bytes_to_f32s(bytes: &[u8]) -> Vec<f32> {
    bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect()
}
