//! The transport abstraction: *how bytes move* between tiers, behind a
//! trait — so the fill chain, extent engine, and retention directory stop
//! assuming every source shares one filesystem.
//!
//! PRs 1–6 moved all data with hard links and copies inside a single
//! process tree; the paper's §5 collective model (and CkIO's interposed
//! buffer layer) are about many compute nodes serving each other's
//! retained data over a real interconnect. [`Transport`] names the four
//! operations that cross that boundary:
//!
//! * `probe` — does the far side hold an archive, and how big is it?
//! * `fetch_archive` — move the whole archive into a local path
//!   (atomically: temp + rename, like every other publish in the crate);
//! * `fetch_range` — move one chunk batch (the extent engine's unit);
//! * `publish` — push a local file to the far side (pre-replication).
//!
//! Every failure is a typed [`FillError`] with `tier`/`source`/
//! `retryable`/`storage` filled in, so the PR-6 retry, per-source
//! deadline, quarantine, and degraded-serving machinery applies to a
//! remote peer exactly as it does to a local sibling — a transport that
//! fails just plugs into existing error handling, no new paths.
//!
//! Two implementations:
//!
//! * [`LocalFsTransport`] — the shared-filesystem impl the old direct
//!   calls become: hard-link mode for sibling groups (zero-copy, the
//!   Chirp torus-neighbor stand-in), bounded chunked-copy mode for the
//!   GFS tier (a hung central store blows the deadline instead of
//!   wedging the fill).
//! * [`SocketTransport`] / [`TransportServer`] — length-prefixed frames
//!   over TCP, one lightweight serving loop per runner, so two real
//!   `StageRunner` processes share a GFS tree and serve each other's
//!   retention across the wire. Socket timeouts map onto the same
//!   per-source deadlines.
//!
//! # Wire format
//!
//! All integers little-endian. One request, one response per round trip;
//! the server serves a request loop per connection until EOF. Since PR 8
//! the client keeps a small pool of idle connections and reuses them
//! across requests (amortizing the TCP handshake the PR-7 follow-up
//! called out); a round trip that fails on a *reused* connection is
//! retried exactly once on a fresh connection after a short backoff, so
//! a peer restart invalidating the pool costs one reconnect, not a
//! failed fill.
//!
//! ```text
//! request:  [u8 op] [u16 name_len] [name bytes] [u64 offset] [u64 len]
//!           op 1 = PROBE   (offset, len ignored)
//!           op 2 = GET     (whole archive; offset, len ignored)
//!           op 3 = RANGE   (len bytes at offset)
//!           op 4 = PUT     (len = payload size; payload bytes follow)
//!           op 5 = PING    (name empty; offset, len ignored — the
//!                           peer-liveness heartbeat)
//!
//! response: [u8 status] [u64 len] [u32 crc32(payload)] [payload: len bytes]
//!           status 0 = OK        (payload: the data; for PROBE an
//!                                 8-byte LE total size; for PUT and
//!                                 PING empty)
//!           status 1 = NOT_FOUND (payload empty; permanent — the far
//!                                 side does not hold the archive)
//!           status 2 = ERROR     (payload: utf8 message; transient —
//!                                 the client re-routes)
//! ```
//!
//! The per-frame `crc32` (PR 8) covers the payload bytes as the server
//! *intended* to send them: the client re-hashes what arrived and a
//! mismatch surfaces as a retryable `FillError { corrupt: true }` — the
//! same shape any other transient probe failure has, so a bit-flipping
//! wire (or a corrupting peer) is retried, re-routed, and quarantined by
//! the existing chain, and wrong bytes never reach a reader.
//!
//! A torn frame (connection dropped mid-payload) surfaces client-side as
//! `UnexpectedEof` → a retryable [`FillError`], indistinguishable from
//! any other torn transfer; a stalled peer trips the socket read timeout
//! → `TimedOut`, which the caller counts as a deadline abort. Fault
//! injection reaches both ends: [`OpClass::Fetch`] rules match the
//! client's pseudo-path `peer/<addr>/<name>` (a `CorruptRange` fetch rule
//! flips a received payload byte — wire damage on the client's side of
//! the TCP stream), [`OpClass::Serve`] rules match the served archive's
//! retained path on the server — a `TruncateAfter` serve rule writes a
//! short payload then drops the connection (the mid-frame-drop fault
//! case), a `Delay` rule stalls the peer, and a `CorruptRange` serve rule
//! flips an outbound payload byte *after* the frame CRC is computed, so
//! the flip is detectable exactly like real wire corruption.

use crate::cio::fault::{FaultInjector, FaultVerdict, FillError, FillTier, OpClass};
use crate::cio::local::{
    publish_copy_deadline_with, publish_link_with, read_range_with, TMP_PREFIX,
};
use anyhow::Result;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Request opcodes (see the module-level wire format).
const OP_PROBE: u8 = 1;
const OP_GET: u8 = 2;
const OP_RANGE: u8 = 3;
const OP_PUT: u8 = 4;
const OP_PING: u8 = 5;

/// Response status codes.
const ST_OK: u8 = 0;
const ST_NOT_FOUND: u8 = 1;
const ST_ERROR: u8 = 2;
/// The server is at its live-connection cap: come back after a backoff.
/// Clients surface this as a *retryable* [`FillError`], so the existing
/// retry/backoff/re-route chain absorbs saturation without new logic.
const ST_BUSY: u8 = 3;

/// Bytes per read/write slice when streaming an archive over a socket or
/// into a file — small enough that deadlines are checked promptly.
const IO_CHUNK: usize = 256 * 1024;

/// How bytes move from one source to the local staging tree. Every
/// method returns a typed [`FillError`] on failure so the caller's
/// retry / re-route / quarantine / degrade machinery applies unchanged
/// regardless of the implementation.
pub trait Transport: Send + Sync {
    /// Which source group this transport pulls from, for health charging
    /// and quarantine. `None` for the anonymous GFS tier.
    fn source(&self) -> Option<u32>;

    /// Does the far side hold `name`? Returns its total size if so.
    /// `Ok(None)` is a definitive miss (not an error).
    fn probe(&self, name: &str) -> Result<Option<u64>, FillError>;

    /// Move the whole archive `name` into `dst`, atomically (the bytes
    /// appear under `dst` complete or not at all). Returns the byte
    /// count. A `deadline` bounds the transfer; blowing it yields a
    /// retryable `TimedOut` error.
    fn fetch_archive(
        &self,
        name: &str,
        dst: &Path,
        deadline: Option<Duration>,
    ) -> Result<u64, FillError>;

    /// Fetch exactly `len` bytes at `offset` of archive `name` — the
    /// extent engine's chunk-batch unit.
    fn fetch_range(
        &self,
        name: &str,
        offset: u64,
        len: usize,
        deadline: Option<Duration>,
    ) -> Result<Vec<u8>, FillError>;

    /// Push the local file `src` to the far side under `name`
    /// (pre-replication / cross-runner publish). Returns the byte count.
    fn publish(&self, src: &Path, name: &str) -> Result<u64, FillError>;

    /// Human-readable endpoint description for diagnostics.
    fn describe(&self) -> String;

    /// Liveness heartbeat: is the far side answering at all? The
    /// peer-lifecycle monitor pings each serving peer on an interval and
    /// renews its directory lease on success; a shared-filesystem
    /// transport is alive by construction, so the default succeeds.
    fn ping(&self) -> Result<(), FillError> {
        Ok(())
    }
}

/// How a [`LocalFsTransport`] moves archive bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LocalMode {
    /// Hard-link publish (zero data movement) — sound only for immutable
    /// files on the same filesystem: the sibling-group torus transfer.
    Link,
    /// Bounded chunked copy — the GFS tier, where the bytes genuinely
    /// cross the hierarchy and a hung store must blow the deadline
    /// rather than wedge the fill.
    Copy,
}

/// The shared-filesystem [`Transport`]: archives live as plain files
/// under `root`, and fetching is a hard link (sibling groups) or a
/// deadline-bounded chunked copy (GFS). This is exactly what the fill
/// chain did before the trait existed, expressed through it — existing
/// failure-injection tests drive the same `publish_link_with` /
/// `read_range_with` primitives underneath.
pub struct LocalFsTransport {
    root: PathBuf,
    mode: LocalMode,
    tier: FillTier,
    source: Option<u32>,
    faults: Option<Arc<FaultInjector>>,
}

impl LocalFsTransport {
    /// A link-mode transport over a sibling group's retained data
    /// directory.
    pub fn sibling(root: PathBuf, source: u32, faults: Option<Arc<FaultInjector>>) -> Self {
        LocalFsTransport {
            root,
            mode: LocalMode::Link,
            tier: FillTier::Neighbor,
            source: Some(source),
            faults,
        }
    }

    /// A copy-mode transport over the central GFS directory.
    pub fn gfs(root: PathBuf, faults: Option<Arc<FaultInjector>>) -> Self {
        LocalFsTransport {
            root,
            mode: LocalMode::Copy,
            tier: FillTier::Gfs,
            source: None,
            faults,
        }
    }

    fn faults(&self) -> Option<&FaultInjector> {
        self.faults.as_deref()
    }

    fn err(&self, err: &anyhow::Error) -> FillError {
        FillError::classify(self.tier, self.source, err)
    }

    /// The path the far side serves `name` from.
    pub fn path_of(&self, name: &str) -> PathBuf {
        self.root.join(name)
    }
}

impl Transport for LocalFsTransport {
    fn source(&self) -> Option<u32> {
        self.source
    }

    fn probe(&self, name: &str) -> Result<Option<u64>, FillError> {
        if name.starts_with(TMP_PREFIX) {
            return Ok(None);
        }
        match std::fs::metadata(self.root.join(name)) {
            Ok(m) if m.is_file() => Ok(Some(m.len())),
            Ok(_) => Ok(None),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => {
                let any = anyhow::Error::from(e).context(format!("probing {name}"));
                Err(self.err(&any))
            }
        }
    }

    fn fetch_archive(
        &self,
        name: &str,
        dst: &Path,
        deadline: Option<Duration>,
    ) -> Result<u64, FillError> {
        let src = self.root.join(name);
        let start = Instant::now();
        let res = match self.mode {
            LocalMode::Link => publish_link_with(self.faults(), &src, dst),
            LocalMode::Copy => publish_copy_deadline_with(self.faults(), &src, dst, deadline),
        };
        match res {
            Ok(n) => {
                // Link mode moves no data, so the deadline can only blow
                // via an injected delay; check post-hoc like the callers
                // always have (copy mode checks inside the loop).
                if self.mode == LocalMode::Link {
                    if let Some(d) = deadline {
                        if start.elapsed() > d {
                            let _ = std::fs::remove_file(dst);
                            let any = anyhow::Error::from(std::io::Error::new(
                                std::io::ErrorKind::TimedOut,
                                format!(
                                    "link fetch of {name} blew its {}ms deadline",
                                    d.as_millis()
                                ),
                            ));
                            return Err(self.err(&any));
                        }
                    }
                }
                Ok(n)
            }
            Err(e) => Err(self.err(&e)),
        }
    }

    fn fetch_range(
        &self,
        name: &str,
        offset: u64,
        len: usize,
        deadline: Option<Duration>,
    ) -> Result<Vec<u8>, FillError> {
        let src = self.root.join(name);
        let start = Instant::now();
        match read_range_with(self.faults(), &src, offset, len) {
            Ok(bytes) => {
                if let Some(d) = deadline {
                    if start.elapsed() > d {
                        let any = anyhow::Error::from(std::io::Error::new(
                            std::io::ErrorKind::TimedOut,
                            format!(
                                "range fetch [{offset}, +{len}) of {name} blew its {}ms deadline",
                                d.as_millis()
                            ),
                        ));
                        return Err(self.err(&any));
                    }
                }
                Ok(bytes)
            }
            Err(e) => Err(self.err(&e)),
        }
    }

    fn publish(&self, src: &Path, name: &str) -> Result<u64, FillError> {
        let dst = self.root.join(name);
        let res = match self.mode {
            LocalMode::Link => publish_link_with(self.faults(), src, &dst),
            LocalMode::Copy => publish_copy_deadline_with(self.faults(), src, &dst, None),
        };
        res.map_err(|e| self.err(&e))
    }

    fn describe(&self) -> String {
        format!("localfs({:?} {})", self.mode, self.root.display())
    }
}

/// What a [`TransportServer`] serves from: the hosting runner's retained
/// archives. `GroupCache` clusters implement this; the trait keeps the
/// server loop ignorant of cache internals while still letting serves
/// feed the directory's load-aware ranking (`begin_serve`/`end_serve`)
/// and the fault layer ([`OpClass::Serve`] rules fire against the
/// retained path being served).
pub trait RecordSource: Send + Sync {
    /// Locate a retained archive by name: the owning group, the on-disk
    /// path, and the total size. `None` → NOT_FOUND on the wire.
    fn locate(&self, name: &str) -> Option<(u32, PathBuf, u64)>;

    /// A serve of `group`'s retention is starting / done (drives
    /// load-aware route ranking on the directory).
    fn begin_serve(&self, group: u32);
    fn end_serve(&self, group: u32);

    /// The failpoint registry consulted per served request.
    fn faults(&self) -> Option<&FaultInjector>;

    /// Accept a pushed archive (PUT). Default: refuse — serving tiers
    /// are read-mostly, and a runner opts in explicitly.
    fn accept(&self, name: &str, _data: &[u8]) -> Result<()> {
        anyhow::bail!("server does not accept pushed archives (refusing {name})")
    }
}

/// Handle on a running [`TransportServer`] loop: the bound address, a
/// served-request counter, and a stop switch.
pub struct ServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    served: Arc<AtomicU64>,
    busy: Arc<AtomicU64>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// The address clients connect to (useful with an ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Requests served so far (all opcodes, including errors).
    pub fn served(&self) -> u64 {
        self.served.load(Ordering::Relaxed)
    }

    /// Connections turned away with `BUSY` because the live-connection
    /// cap was reached.
    pub fn busy_rejections(&self) -> u64 {
        self.busy.load(Ordering::Relaxed)
    }

    /// Stop the accept loop and join it. In-flight connections finish
    /// their current request.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Release);
        // Self-connect to unblock the accept loop.
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(500));
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// The per-runner serving loop: binds a TCP listener, accepts
/// connections, and answers wire-format requests from a [`RecordSource`].
/// One accept thread plus one short-lived thread per connection — the
/// "lightweight serving loop per runner" the multi-node story needs,
/// deliberately boring (no async runtime, no pooling) so correctness
/// under faults stays auditable.
pub struct TransportServer;

impl TransportServer {
    /// Bind `addr` (use `127.0.0.1:0` for an ephemeral port) and serve
    /// `source` until the returned handle is stopped or dropped, with no
    /// live-connection bound.
    pub fn serve(addr: &str, source: Arc<dyn RecordSource>) -> Result<ServerHandle> {
        TransportServer::serve_capped(addr, source, usize::MAX)
    }

    /// [`TransportServer::serve`] with a cap on concurrent live
    /// connections (the thread-per-connection bound): a connection
    /// accepted at the cap is answered with one `BUSY` frame and closed
    /// instead of getting a serving thread. Clients see a retryable
    /// [`FillError`] and come back through the normal backoff, so
    /// saturation degrades to added latency — never a wedged latch or an
    /// unbounded thread pile.
    pub fn serve_capped(
        addr: &str,
        source: Arc<dyn RecordSource>,
        max_live: usize,
    ) -> Result<ServerHandle> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let served = Arc::new(AtomicU64::new(0));
        let busy = Arc::new(AtomicU64::new(0));
        let live = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let (stop2, served2, busy2) = (Arc::clone(&stop), Arc::clone(&served), Arc::clone(&busy));
        let thread = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::Acquire) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                if live.fetch_add(1, Ordering::AcqRel) >= max_live {
                    live.fetch_sub(1, Ordering::AcqRel);
                    busy2.fetch_add(1, Ordering::Relaxed);
                    // Answer the client's first (in-flight) request with
                    // a BUSY frame off-thread so a slow reader cannot
                    // stall the accept loop, then drop the connection.
                    std::thread::spawn(move || {
                        let mut stream = stream;
                        let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
                        let _ = respond(
                            &mut stream,
                            ST_BUSY,
                            b"server at live-connection capacity; retry",
                        );
                    });
                    continue;
                }
                let src = Arc::clone(&source);
                let served = Arc::clone(&served2);
                let live = Arc::clone(&live);
                std::thread::spawn(move || {
                    let _ = serve_connection(stream, &*src, &served);
                    live.fetch_sub(1, Ordering::AcqRel);
                });
            }
        });
        Ok(ServerHandle { addr: local, stop, served, busy, thread: Some(thread) })
    }
}

/// Serve requests on one connection until EOF or an unrecoverable
/// transport error.
fn serve_connection(
    mut stream: TcpStream,
    source: &dyn RecordSource,
    served: &AtomicU64,
) -> Result<()> {
    // A peer that connects and then says nothing should not pin a server
    // thread forever.
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    loop {
        let mut op = [0u8; 1];
        match stream.read_exact(&mut op) {
            Ok(()) => {}
            Err(_) => return Ok(()), // EOF or dead peer: connection done
        }
        let mut len2 = [0u8; 2];
        stream.read_exact(&mut len2)?;
        let name_len = u16::from_le_bytes(len2) as usize;
        let mut name_buf = vec![0u8; name_len];
        stream.read_exact(&mut name_buf)?;
        let name = String::from_utf8(name_buf)?;
        let mut u64s = [0u8; 16];
        stream.read_exact(&mut u64s)?;
        let offset = u64::from_le_bytes(u64s[..8].try_into().unwrap());
        let len = u64::from_le_bytes(u64s[8..].try_into().unwrap());
        served.fetch_add(1, Ordering::Relaxed);
        match op[0] {
            OP_PROBE => {
                match source.locate(&name) {
                    Some((_, _, total)) => {
                        respond(&mut stream, ST_OK, &total.to_le_bytes())?;
                    }
                    None => respond(&mut stream, ST_NOT_FOUND, &[])?,
                }
            }
            OP_GET | OP_RANGE => {
                let Some((group, path, total)) = source.locate(&name) else {
                    respond(&mut stream, ST_NOT_FOUND, &[])?;
                    continue;
                };
                let (off, n) = if op[0] == OP_GET {
                    (0, total as usize)
                } else {
                    (offset, len as usize)
                };
                if off.saturating_add(n as u64) > total {
                    respond(
                        &mut stream,
                        ST_ERROR,
                        format!("range [{off}, +{n}) exceeds {total}-byte {name}").as_bytes(),
                    )?;
                    continue;
                }
                // The server-side failpoint: evaluated against the
                // retained path, so tests can tear, stall, or bit-flip a
                // specific peer's outbound frames.
                let mut torn = None;
                let mut flip = None;
                match source
                    .faults()
                    .map_or(FaultVerdict::Proceed, |f| f.evaluate(OpClass::Serve, &path))
                {
                    FaultVerdict::Proceed => {}
                    FaultVerdict::Fail(e) => {
                        respond(&mut stream, ST_ERROR, format!("serve fault: {e}").as_bytes())?;
                        continue;
                    }
                    FaultVerdict::Truncate(cut) => torn = Some(cut as usize),
                    FaultVerdict::Corrupt(off) => flip = Some(off),
                }
                source.begin_serve(group);
                let data = read_range_with(None, &path, off, n);
                source.end_serve(group);
                match data {
                    Ok(mut bytes) => {
                        // The frame CRC always covers the payload as
                        // read from disk; an injected flip lands after
                        // hashing, so the wire carries a frame whose CRC
                        // does not match its bytes — exactly what real
                        // in-flight corruption looks like to the client.
                        let crc = crc32fast::hash(&bytes);
                        if let Some(off) = flip {
                            crate::cio::fault::corrupt_buffer(&mut bytes, off);
                        }
                        if let Some(cut) = torn {
                            // Mid-frame drop: claim the full payload,
                            // send a prefix, kill the connection.
                            let cut = cut.min(bytes.len());
                            stream.write_all(&[ST_OK])?;
                            stream.write_all(&(bytes.len() as u64).to_le_bytes())?;
                            stream.write_all(&crc.to_le_bytes())?;
                            stream.write_all(&bytes[..cut])?;
                            let _ = stream.flush();
                            return Ok(());
                        }
                        respond_framed(&mut stream, ST_OK, crc, &bytes)?;
                    }
                    Err(e) => {
                        respond(&mut stream, ST_ERROR, format!("{e:#}").as_bytes())?;
                    }
                }
            }
            OP_PUT => {
                let mut data = vec![0u8; len as usize];
                stream.read_exact(&mut data)?;
                match source.accept(&name, &data) {
                    Ok(()) => respond(&mut stream, ST_OK, &[])?,
                    Err(e) => respond(&mut stream, ST_ERROR, format!("{e:#}").as_bytes())?,
                }
            }
            OP_PING => {
                // The liveness heartbeat: an empty OK frame. Reaching
                // this line at all is the answer.
                respond(&mut stream, ST_OK, &[])?;
            }
            other => {
                respond(&mut stream, ST_ERROR, format!("unknown opcode {other}").as_bytes())?;
            }
        }
    }
}

fn respond(stream: &mut TcpStream, status: u8, payload: &[u8]) -> Result<()> {
    respond_framed(stream, status, crc32fast::hash(payload), payload)
}

/// Write a response frame with an explicit CRC — the serve path computes
/// the hash before any injected corruption touches the payload.
fn respond_framed(stream: &mut TcpStream, status: u8, crc: u32, payload: &[u8]) -> Result<()> {
    stream.write_all(&[status])?;
    stream.write_all(&(payload.len() as u64).to_le_bytes())?;
    stream.write_all(&crc.to_le_bytes())?;
    let mut sent = 0;
    while sent < payload.len() {
        let n = (payload.len() - sent).min(IO_CHUNK);
        stream.write_all(&payload[sent..sent + n])?;
        sent += n;
    }
    stream.flush()?;
    Ok(())
}

/// How many idle connections a [`SocketTransport`] keeps for reuse.
const POOL_CAP: usize = 4;

/// Backoff before retrying a round trip that failed on a *reused*
/// connection — long enough to let a restarting peer finish binding,
/// short enough to stay invisible next to a fill deadline.
const RECONNECT_BACKOFF: Duration = Duration::from_millis(5);

/// The cross-process [`Transport`]: length-prefixed frames over TCP to a
/// peer runner's [`TransportServer`]. Connections are pooled and reused
/// across requests; a request that fails on a reused connection is
/// retried once on a fresh one after a short backoff (a peer restart
/// invalidates the whole pool for the price of one reconnect). Socket
/// read/write timeouts are derived from the caller's deadline (or the
/// transport's default), so a stalled peer surfaces as a retryable
/// `TimedOut` [`FillError`] — the same shape a blown local deadline has —
/// and the retry chain re-routes / quarantines it with zero new logic.
/// Every response frame's CRC is re-hashed on arrival; a mismatch is a
/// retryable `corrupt` [`FillError`], so wire damage feeds the same
/// retry → re-route → quarantine chain and never reaches a reader.
pub struct SocketTransport {
    addr: String,
    source: Option<u32>,
    tier: FillTier,
    connect_timeout: Duration,
    io_timeout: Duration,
    faults: Option<Arc<FaultInjector>>,
    pool: std::sync::Mutex<Vec<TcpStream>>,
    pool_hits: AtomicU64,
    reconnects: AtomicU64,
}

impl SocketTransport {
    /// A transport to the peer runner serving `source`'s retention at
    /// `addr` (e.g. `"127.0.0.1:41300"`).
    pub fn new(addr: &str, source: u32) -> SocketTransport {
        SocketTransport {
            addr: addr.to_string(),
            source: Some(source),
            tier: FillTier::Neighbor,
            connect_timeout: Duration::from_millis(500),
            io_timeout: Duration::from_secs(5),
            faults: None,
            pool: std::sync::Mutex::new(Vec::new()),
            pool_hits: AtomicU64::new(0),
            reconnects: AtomicU64::new(0),
        }
    }

    /// Requests served off a pooled (reused) connection so far.
    pub fn pool_hits(&self) -> u64 {
        self.pool_hits.load(Ordering::Relaxed)
    }

    /// Round trips that failed on a reused connection and were replayed
    /// on a fresh one — each is a stale pooled connection detected and
    /// replaced.
    pub fn reconnects(&self) -> u64 {
        self.reconnects.load(Ordering::Relaxed)
    }

    /// Override the connect / request timeouts (defaults 500 ms / 5 s).
    /// The per-call deadline, when tighter, wins.
    pub fn with_timeouts(mut self, connect: Duration, io: Duration) -> SocketTransport {
        self.connect_timeout = connect;
        self.io_timeout = io;
        self
    }

    /// Attach a failpoint registry; [`OpClass::Fetch`] rules match the
    /// pseudo-path `peer/<addr>/<name>`.
    pub fn with_faults(mut self, faults: Arc<FaultInjector>) -> SocketTransport {
        self.faults = Some(faults);
        self
    }

    fn err(&self, retryable: bool, msg: String) -> FillError {
        FillError {
            tier: self.tier,
            source: self.source,
            retryable,
            storage: false,
            timeout: false,
            corrupt: false,
            msg,
        }
    }

    /// A blown socket deadline — retryable, and flagged so the caller
    /// counts it as a deadline abort ([`crate::cio::fault::is_timeout`]).
    fn timeout_err(&self, msg: String) -> FillError {
        FillError {
            tier: self.tier,
            source: self.source,
            retryable: true,
            storage: false,
            timeout: true,
            corrupt: false,
            msg,
        }
    }

    /// A frame whose payload does not hash to its CRC — retryable, and
    /// flagged `corrupt` so the caller counts the detection and the
    /// health ledger can quarantine a repeat offender.
    fn corrupt_err(&self, msg: String) -> FillError {
        FillError::corruption(self.tier, self.source, msg)
    }

    fn io_err(&self, e: &std::io::Error, what: &str) -> FillError {
        // A read timeout surfaces as WouldBlock on Unix; normalize to
        // the TimedOut shape deadlines use everywhere else.
        let timed_out = matches!(
            e.kind(),
            std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock
        );
        if timed_out {
            self.timeout_err(format!("deadline failure {what} {}: {e}", self.addr))
        } else {
            self.err(true, format!("io failure {what} {}: {e}", self.addr))
        }
    }

    /// Evaluate the client-side failpoint for a request on `name`.
    /// `Ok(Some(off))` means an injected `CorruptRange` should flip the
    /// received payload byte at `off` — wire damage on the client's side
    /// of the stream, which the frame CRC then catches.
    fn client_fault(&self, name: &str) -> Result<Option<u64>, FillError> {
        let Some(f) = self.faults.as_deref() else { return Ok(None) };
        let pseudo = PathBuf::from(format!("peer/{}/{name}", self.addr));
        match f.evaluate(OpClass::Fetch, &pseudo) {
            FaultVerdict::Proceed => Ok(None),
            FaultVerdict::Fail(e) => Err(self.io_err(&e, "requesting")),
            FaultVerdict::Truncate(n) => Err(self.err(
                true,
                format!("injected torn fetch of {name} from {} after {n} bytes", self.addr),
            )),
            FaultVerdict::Corrupt(off) => Ok(Some(off)),
        }
    }

    /// Pop an idle pooled connection, if any.
    fn pooled(&self) -> Option<TcpStream> {
        self.pool.lock().unwrap().pop()
    }

    /// Return a connection that finished a clean round trip to the pool.
    fn park(&self, stream: TcpStream) {
        let mut pool = self.pool.lock().unwrap();
        if pool.len() < POOL_CAP {
            pool.push(stream);
        }
    }

    /// Open a fresh connection with the request timeouts applied.
    fn connect(&self, timeout: Duration) -> Result<TcpStream, FillError> {
        let addr = self
            .addr
            .to_socket_addrs()
            .map_err(|e| self.err(false, format!("resolving {}: {e}", self.addr)))?
            .next()
            .ok_or_else(|| self.err(false, format!("{} resolves to nothing", self.addr)))?;
        TcpStream::connect_timeout(&addr, self.connect_timeout.min(timeout))
            .map_err(|e| self.io_err(&e, "connecting to"))
    }

    /// One request/response round trip. Returns `(status, payload)`.
    /// Prefers a pooled connection; a failure on a *reused* connection
    /// (other than a deadline, whose budget is already spent) is retried
    /// exactly once on a fresh connection after a short backoff — that
    /// is the reconnect-on-stale path.
    fn request(
        &self,
        op: u8,
        name: &str,
        offset: u64,
        len: u64,
        body: Option<&[u8]>,
        deadline: Option<Duration>,
    ) -> Result<(u8, Vec<u8>), FillError> {
        let flip = self.client_fault(name)?;
        let timeout = deadline.map_or(self.io_timeout, |d| d.min(self.io_timeout));
        let (mut stream, mut reused) = match self.pooled() {
            Some(s) => (s, true),
            None => (self.connect(timeout)?, false),
        };
        loop {
            if reused {
                self.pool_hits.fetch_add(1, Ordering::Relaxed);
            }
            match self.round_trip(&mut stream, op, name, offset, len, body, timeout) {
                Ok((status, mut payload, crc)) => {
                    if let Some(off) = flip {
                        crate::cio::fault::corrupt_buffer(&mut payload, off);
                    }
                    if status == ST_OK && crc32fast::hash(&payload) != crc {
                        // Do not park a connection that just delivered a
                        // bad frame; the next request starts clean.
                        return Err(self.corrupt_err(format!(
                            "frame CRC mismatch on {name} from {} ({} bytes)",
                            self.addr,
                            payload.len()
                        )));
                    }
                    self.park(stream);
                    return Ok((status, payload));
                }
                Err(e) => {
                    if reused && !e.timeout {
                        // A reused connection can be stale (peer
                        // restarted, idle timeout fired): drop it, back
                        // off briefly, replay once on a fresh one.
                        drop(stream);
                        std::thread::sleep(RECONNECT_BACKOFF);
                        self.reconnects.fetch_add(1, Ordering::Relaxed);
                        stream = self.connect(timeout)?;
                        reused = false;
                        continue;
                    }
                    return Err(e);
                }
            }
        }
    }

    /// Write one request and read one response frame on `stream`.
    /// Returns `(status, payload, frame_crc)`.
    #[allow(clippy::too_many_arguments)]
    fn round_trip(
        &self,
        stream: &mut TcpStream,
        op: u8,
        name: &str,
        offset: u64,
        len: u64,
        body: Option<&[u8]>,
        timeout: Duration,
    ) -> Result<(u8, Vec<u8>, u32), FillError> {
        stream
            .set_read_timeout(Some(timeout))
            .and_then(|()| stream.set_write_timeout(Some(timeout)))
            .map_err(|e| self.io_err(&e, "configuring"))?;
        let started = Instant::now();
        let name_bytes = name.as_bytes();
        let mut req = Vec::with_capacity(1 + 2 + name_bytes.len() + 16);
        req.push(op);
        req.extend_from_slice(&(name_bytes.len() as u16).to_le_bytes());
        req.extend_from_slice(name_bytes);
        req.extend_from_slice(&offset.to_le_bytes());
        req.extend_from_slice(&len.to_le_bytes());
        stream.write_all(&req).map_err(|e| self.io_err(&e, "sending request to"))?;
        if let Some(body) = body {
            stream.write_all(body).map_err(|e| self.io_err(&e, "sending payload to"))?;
        }
        let mut head = [0u8; 13];
        stream.read_exact(&mut head).map_err(|e| self.io_err(&e, "reading header from"))?;
        let status = head[0];
        let payload_len = u64::from_le_bytes(head[1..9].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(head[9..].try_into().unwrap());
        let mut payload = vec![0u8; payload_len];
        let mut got = 0;
        while got < payload_len {
            // Chunked so a glacial (but not stalled) peer still blows
            // the overall deadline instead of resetting the socket
            // timeout with each trickled byte.
            if started.elapsed() > timeout {
                return Err(self.timeout_err(format!(
                    "deadline failure reading payload from {}: {got}/{payload_len} bytes in {}ms",
                    self.addr,
                    timeout.as_millis()
                )));
            }
            let n = (payload_len - got).min(IO_CHUNK);
            stream
                .read_exact(&mut payload[got..got + n])
                .map_err(|e| self.io_err(&e, "reading payload from"))?;
            got += n;
        }
        Ok((status, payload, crc))
    }

    /// Interpret a non-OK status as the typed error it means.
    fn status_err(&self, status: u8, payload: Vec<u8>, name: &str) -> FillError {
        match status {
            ST_NOT_FOUND => {
                self.err(false, format!("{name} not held by peer {}", self.addr))
            }
            ST_BUSY => self.err(
                true,
                format!("peer {} busy (connection cap) serving {name}", self.addr),
            ),
            _ => {
                let msg = String::from_utf8_lossy(&payload).into_owned();
                self.err(true, format!("peer {} failed serving {name}: {msg}", self.addr))
            }
        }
    }
}

impl Transport for SocketTransport {
    fn source(&self) -> Option<u32> {
        self.source
    }

    fn probe(&self, name: &str) -> Result<Option<u64>, FillError> {
        let (status, payload) = self.request(OP_PROBE, name, 0, 0, None, None)?;
        match status {
            ST_OK if payload.len() == 8 => {
                Ok(Some(u64::from_le_bytes(payload.try_into().unwrap())))
            }
            ST_OK => Err(self.err(true, format!("malformed probe reply for {name}"))),
            ST_NOT_FOUND => Ok(None),
            other => Err(self.status_err(other, payload, name)),
        }
    }

    fn fetch_archive(
        &self,
        name: &str,
        dst: &Path,
        deadline: Option<Duration>,
    ) -> Result<u64, FillError> {
        let (status, payload) = self.request(OP_GET, name, 0, 0, None, deadline)?;
        if status != ST_OK {
            return Err(self.status_err(status, payload, name));
        }
        // Land the bytes atomically, like every publish in the crate.
        let stage = || -> anyhow::Result<u64> {
            let dir = dst.parent().ok_or_else(|| anyhow::anyhow!("no parent for fetch dst"))?;
            let base = dst
                .file_name()
                .and_then(|n| n.to_str())
                .ok_or_else(|| anyhow::anyhow!("non-utf8 fetch dst"))?;
            static NET_SEQ: AtomicU64 = AtomicU64::new(0);
            let seq = NET_SEQ.fetch_add(1, Ordering::Relaxed);
            let tmp =
                dir.join(format!("{TMP_PREFIX}net-{}-{seq}-{base}", std::process::id()));
            std::fs::write(&tmp, &payload)?;
            if let Err(e) = std::fs::rename(&tmp, dst) {
                let _ = std::fs::remove_file(&tmp);
                return Err(e.into());
            }
            Ok(payload.len() as u64)
        };
        stage().map_err(|e| FillError::classify(self.tier, self.source, &e))
    }

    fn fetch_range(
        &self,
        name: &str,
        offset: u64,
        len: usize,
        deadline: Option<Duration>,
    ) -> Result<Vec<u8>, FillError> {
        let (status, payload) =
            self.request(OP_RANGE, name, offset, len as u64, None, deadline)?;
        if status != ST_OK {
            return Err(self.status_err(status, payload, name));
        }
        if payload.len() != len {
            return Err(self.err(
                true,
                format!(
                    "short range reply for {name}: wanted {len} at {offset}, got {}",
                    payload.len()
                ),
            ));
        }
        Ok(payload)
    }

    fn publish(&self, src: &Path, name: &str) -> Result<u64, FillError> {
        let data = std::fs::read(src)
            .map_err(|e| self.err(true, format!("reading {} for push: {e}", src.display())))?;
        let (status, payload) =
            self.request(OP_PUT, name, 0, data.len() as u64, Some(&data), None)?;
        if status != ST_OK {
            return Err(self.status_err(status, payload, name));
        }
        Ok(data.len() as u64)
    }

    fn describe(&self) -> String {
        format!("socket({} -> group {:?})", self.addr, self.source)
    }

    fn ping(&self) -> Result<(), FillError> {
        let (status, payload) = self.request(OP_PING, "", 0, 0, None, None)?;
        if status != ST_OK {
            return Err(self.status_err(status, payload, "ping"));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cio::fault::FaultAction;
    use std::sync::Mutex;

    /// A RecordSource over a plain directory, for wire-level tests.
    struct DirSource {
        root: PathBuf,
        group: u32,
        faults: Option<Arc<FaultInjector>>,
        accepted: Mutex<Vec<String>>,
    }

    impl RecordSource for DirSource {
        fn locate(&self, name: &str) -> Option<(u32, PathBuf, u64)> {
            let p = self.root.join(name);
            let m = std::fs::metadata(&p).ok()?;
            m.is_file().then(|| (self.group, p, m.len()))
        }
        fn begin_serve(&self, _group: u32) {}
        fn end_serve(&self, _group: u32) {}
        fn faults(&self) -> Option<&FaultInjector> {
            self.faults.as_deref()
        }
        fn accept(&self, name: &str, data: &[u8]) -> Result<()> {
            std::fs::write(self.root.join(name), data)?;
            self.accepted.lock().unwrap().push(name.to_string());
            Ok(())
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("cio-transport-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn serve_dir(root: &Path, faults: Option<Arc<FaultInjector>>) -> ServerHandle {
        let src = Arc::new(DirSource {
            root: root.to_path_buf(),
            group: 0,
            faults,
            accepted: Mutex::new(Vec::new()),
        });
        TransportServer::serve("127.0.0.1:0", src).unwrap()
    }

    #[test]
    fn socket_round_trip_probe_get_range_put() {
        let root = tmpdir("rt");
        let body: Vec<u8> = (0..100_000u32).map(|i| (i % 251) as u8).collect();
        std::fs::write(root.join("a.cioar"), &body).unwrap();
        let server = serve_dir(&root, None);
        let t = SocketTransport::new(&server.addr().to_string(), 0);

        assert_eq!(t.probe("a.cioar").unwrap(), Some(body.len() as u64));
        assert_eq!(t.probe("missing.cioar").unwrap(), None);

        let got = t.fetch_range("a.cioar", 777, 4096, None).unwrap();
        assert_eq!(got, body[777..777 + 4096], "range reads are byte-exact");

        let dst = root.join("fetched.cioar");
        let n = t.fetch_archive("a.cioar", &dst, None).unwrap();
        assert_eq!(n, body.len() as u64);
        assert_eq!(std::fs::read(&dst).unwrap(), body, "whole fetch is byte-exact");

        let push_src = root.join("outbound.bin");
        std::fs::write(&push_src, b"pushed bytes").unwrap();
        t.publish(&push_src, "pushed.cioar").unwrap();
        assert_eq!(std::fs::read(root.join("pushed.cioar")).unwrap(), b"pushed bytes");
        assert!(server.served() >= 5);
    }

    #[test]
    fn not_found_is_permanent_server_error_is_transient() {
        let root = tmpdir("nf");
        let server = serve_dir(&root, None);
        let t = SocketTransport::new(&server.addr().to_string(), 3);
        let e = t.fetch_archive("gone.cioar", &root.join("d"), None).unwrap_err();
        assert!(!e.retryable, "NOT_FOUND must be permanent: {e}");
        assert_eq!(e.source, Some(3));

        let faults = Arc::new(FaultInjector::new());
        faults.inject(OpClass::Serve, "b.cioar", FaultAction::Error);
        std::fs::write(root.join("b.cioar"), b"x").unwrap();
        let server2 = serve_dir(&root, Some(Arc::clone(&faults)));
        let t2 = SocketTransport::new(&server2.addr().to_string(), 3);
        let e2 = t2.fetch_range("b.cioar", 0, 1, None).unwrap_err();
        assert!(e2.retryable, "a server-side fault must be transient: {e2}");
        assert!(faults.injected() >= 1);
    }

    #[test]
    fn mid_frame_drop_is_retryable_torn_transfer() {
        let root = tmpdir("torn");
        let body = vec![7u8; 50_000];
        std::fs::write(root.join("c.cioar"), &body).unwrap();
        let faults = Arc::new(FaultInjector::new());
        faults.inject(OpClass::Serve, "c.cioar", FaultAction::TruncateAfter(1000));
        let server = serve_dir(&root, Some(faults));
        let t = SocketTransport::new(&server.addr().to_string(), 1);
        let e = t.fetch_range("c.cioar", 0, body.len(), None).unwrap_err();
        assert!(e.retryable, "a torn frame re-routes: {e}");
        assert_eq!(e.tier, FillTier::Neighbor);
    }

    #[test]
    fn stalled_peer_blows_the_deadline() {
        let root = tmpdir("stall");
        std::fs::write(root.join("s.cioar"), vec![1u8; 1000]).unwrap();
        let faults = Arc::new(FaultInjector::new());
        faults.inject(OpClass::Serve, "s.cioar", FaultAction::Delay(Duration::from_millis(400)));
        let server = serve_dir(&root, Some(faults));
        let t = SocketTransport::new(&server.addr().to_string(), 2);
        let start = Instant::now();
        let e = t
            .fetch_range("s.cioar", 0, 1000, Some(Duration::from_millis(60)))
            .unwrap_err();
        assert!(e.retryable, "a stalled peer is transient: {e}");
        assert!(e.msg.contains("deadline"), "stall surfaces as a deadline failure: {e}");
        assert!(
            start.elapsed() < Duration::from_millis(350),
            "client gave up before the stall ended"
        );
    }

    #[test]
    fn localfs_link_and_copy_modes_fetch_byte_exact() {
        let root = tmpdir("lfs");
        let body = vec![9u8; 12_345];
        std::fs::write(root.join("l.cioar"), &body).unwrap();
        let link = LocalFsTransport::sibling(root.clone(), 4, None);
        assert_eq!(link.probe("l.cioar").unwrap(), Some(body.len() as u64));
        assert_eq!(link.probe("nope").unwrap(), None);
        let d1 = root.join("via-link.cioar");
        assert_eq!(link.fetch_archive("l.cioar", &d1, None).unwrap(), body.len() as u64);
        assert_eq!(std::fs::read(&d1).unwrap(), body);

        let copy = LocalFsTransport::gfs(root.clone(), None);
        let d2 = root.join("via-copy.cioar");
        assert_eq!(copy.fetch_archive("l.cioar", &d2, None).unwrap(), body.len() as u64);
        assert_eq!(std::fs::read(&d2).unwrap(), body);
        assert_eq!(copy.fetch_range("l.cioar", 100, 200, None).unwrap(), body[100..300]);
    }

    #[test]
    fn gfs_copy_deadline_blows_as_retryable_timeout() {
        let root = tmpdir("gdl");
        std::fs::write(root.join("g.cioar"), vec![3u8; 4096]).unwrap();
        let faults = Arc::new(FaultInjector::new());
        faults.inject(
            OpClass::PublishCopy,
            "slow.cioar",
            FaultAction::Delay(Duration::from_millis(120)),
        );
        let copy = LocalFsTransport::gfs(root.clone(), Some(faults));
        let e = copy
            .fetch_archive("g.cioar", &root.join("slow.cioar"), Some(Duration::from_millis(20)))
            .unwrap_err();
        assert!(e.retryable, "a blown GFS deadline must be retryable: {e}");
        let any = anyhow::Error::new(e);
        assert!(crate::cio::fault::is_timeout(&any), "and recognizable as a timeout");
        assert!(crate::cio::fault::is_retryable(&any), "through the anyhow chain too");
    }

    #[test]
    fn corrupted_wire_frame_is_detected_by_crc() {
        let root = tmpdir("crc");
        let body: Vec<u8> = (0..40_000u32).map(|i| (i % 241) as u8).collect();
        std::fs::write(root.join("w.cioar"), &body).unwrap();
        let faults = Arc::new(FaultInjector::new());
        faults.inject_times(OpClass::Serve, "w.cioar", FaultAction::CorruptRange(123), 1);
        let server = serve_dir(&root, Some(Arc::clone(&faults)));
        let t = SocketTransport::new(&server.addr().to_string(), 6);

        let e = t.fetch_range("w.cioar", 0, body.len(), None).unwrap_err();
        assert!(e.corrupt, "a CRC mismatch is flagged corrupt: {e}");
        assert!(e.retryable, "and retryable, feeding the re-route chain: {e}");
        assert_eq!(e.source, Some(6), "charged to the serving group");
        let any = anyhow::Error::new(e);
        assert!(crate::cio::fault::is_corrupt(&any), "recognizable through the chain");

        // The rule fired once; the retry (what the fill chain would do)
        // gets clean, byte-exact data.
        let got = t.fetch_range("w.cioar", 0, body.len(), None).unwrap();
        assert_eq!(got, body, "post-corruption retry is byte-exact");
    }

    #[test]
    fn client_side_fetch_corruption_is_caught_too() {
        let root = tmpdir("ccrc");
        let body = vec![0xA5u8; 9000];
        std::fs::write(root.join("x.cioar"), &body).unwrap();
        let server = serve_dir(&root, None);
        let faults = Arc::new(FaultInjector::new());
        faults.inject_times(OpClass::Fetch, "x.cioar", FaultAction::CorruptRange(0), 1);
        let t =
            SocketTransport::new(&server.addr().to_string(), 2).with_faults(Arc::clone(&faults));
        let e = t.fetch_range("x.cioar", 0, body.len(), None).unwrap_err();
        assert!(e.corrupt && e.retryable, "client-side flip caught by the frame CRC: {e}");
        assert_eq!(t.fetch_range("x.cioar", 0, body.len(), None).unwrap(), body);
    }

    #[test]
    fn ping_round_trip_answers_ok() {
        let root = tmpdir("ping");
        let server = serve_dir(&root, None);
        let t = SocketTransport::new(&server.addr().to_string(), 0);
        t.ping().expect("a live peer answers the heartbeat");
        assert!(server.served() >= 1);

        // LocalFs transports share a filesystem with the peer: alive by
        // construction.
        LocalFsTransport::gfs(root.clone(), None).ping().unwrap();
    }

    #[test]
    fn pooled_connections_are_reused_across_requests() {
        let root = tmpdir("pool");
        let body = vec![4u8; 20_000];
        std::fs::write(root.join("p.cioar"), &body).unwrap();
        let server = serve_dir(&root, None);
        let t = SocketTransport::new(&server.addr().to_string(), 0);
        assert_eq!(t.probe("p.cioar").unwrap(), Some(body.len() as u64));
        assert_eq!(t.pool_hits(), 0, "first request had nothing to reuse");
        for _ in 0..3 {
            assert_eq!(t.fetch_range("p.cioar", 0, 1024, None).unwrap(), body[..1024]);
        }
        assert!(
            t.pool_hits() >= 3,
            "subsequent requests ride the pooled connection (hits = {})",
            t.pool_hits()
        );
        assert_eq!(t.reconnects(), 0, "no stale connections on a healthy peer");
    }
}
