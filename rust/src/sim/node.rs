//! Compute-node bookkeeping: role, core occupancy, and the node's LFS.
//!
//! The paper's §5 partitions compute nodes per workload into
//! application-executing nodes and data-serving (IFS) nodes — Figure 8's
//! "allocation and mapping of compute nodes to IFS servers". [`NodeState`]
//! carries that role plus the per-node RAM disk and busy-core count the
//! dispatcher uses.

use crate::sim::lfs::Lfs;

/// What a compute node is provisioned to do for the current workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Runs application tasks.
    Compute,
    /// Dedicated chirp/MosaStore data server (its cores run no tasks).
    IfsServer,
}

/// Per-node simulation state.
#[derive(Debug, Clone)]
pub struct NodeState {
    /// Node id (dense, 0-based).
    pub id: u32,
    /// Provisioned role.
    pub role: Role,
    /// ION this node forwards IO through.
    pub ion: u32,
    /// IFS group serving this node's staged input data.
    pub ifs_group: u32,
    /// The node's RAM-disk LFS.
    pub lfs: Lfs,
    /// Cores currently running a task.
    pub busy_cores: u32,
    /// Total cores.
    pub cores: u32,
    /// Tasks completed on this node (diagnostics).
    pub tasks_done: u64,
}

impl NodeState {
    /// Fresh compute node.
    pub fn new(id: u32, ion: u32, ifs_group: u32, cores: u32, lfs_capacity: u64) -> Self {
        NodeState {
            id,
            role: Role::Compute,
            ion,
            ifs_group,
            lfs: Lfs::new(lfs_capacity),
            busy_cores: 0,
            cores,
            tasks_done: 0,
        }
    }

    /// Idle cores available for dispatch.
    pub fn idle_cores(&self) -> u32 {
        if self.role == Role::IfsServer {
            return 0;
        }
        self.cores - self.busy_cores
    }

    /// Claim one core for a task.
    pub fn claim_core(&mut self) {
        assert!(self.idle_cores() > 0, "node {} has no idle core", self.id);
        self.busy_cores += 1;
    }

    /// Release a core at task completion.
    pub fn release_core(&mut self) {
        assert!(self.busy_cores > 0, "node {} releasing idle core", self.id);
        self.busy_cores -= 1;
        self.tasks_done += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::gib;

    #[test]
    fn core_accounting() {
        let mut n = NodeState::new(7, 0, 0, 4, gib(1));
        assert_eq!(n.idle_cores(), 4);
        n.claim_core();
        n.claim_core();
        assert_eq!(n.idle_cores(), 2);
        n.release_core();
        assert_eq!(n.idle_cores(), 3);
        assert_eq!(n.tasks_done, 1);
    }

    #[test]
    fn ifs_server_runs_no_tasks() {
        let mut n = NodeState::new(0, 0, 0, 4, gib(1));
        n.role = Role::IfsServer;
        assert_eq!(n.idle_cores(), 0);
    }

    #[test]
    #[should_panic(expected = "no idle core")]
    fn overclaim_panics() {
        let mut n = NodeState::new(0, 0, 0, 1, gib(1));
        n.claim_core();
        n.claim_core();
    }

    #[test]
    #[should_panic(expected = "releasing idle core")]
    fn overrelease_panics() {
        let mut n = NodeState::new(0, 0, 0, 1, gib(1));
        n.release_core();
    }
}
