//! Fluid flow-level network model with processor-sharing bandwidth
//! allocation.
//!
//! A *flow* moves `bytes` across a *path* of shared [`Resource`]s (NICs,
//! tree links, file-system servers, aggregate bisection caps). At any
//! instant a flow's rate is `min over r in path (capacity_r / load_r)`
//! where `load_r` is the number of flows currently crossing `r` — the
//! classic max-min-ish fluid approximation used by flow-level simulators.
//! Rates change only when a flow starts or completes, so the simulation
//! advances analytically between those events; no per-packet work.
//!
//! ## Scaling: path groups + incremental repricing
//!
//! The paper's workloads are highly symmetric (64 clients per IFS server,
//! thousands of nodes writing to one GFS), so flows are grouped by their
//! path signature; all members of a group share one rate, and each group
//! keeps its members in a BTree ordered by *virtual finish work*
//! (remaining bytes at insert + the group's attained service at insert).
//!
//! The first implementation recomputed every group's rate on every event
//! — profiled at >50% of a 96K-processor sweep's wall time (EXPERIMENTS.md
//! §Perf). This version is **incremental**:
//!
//! * groups live in stable slots (slab + free list), each with its own
//!   `last_update` so attained service integrates lazily per group;
//! * each resource keeps the list of group slots crossing it; a load
//!   change reprices only those groups;
//! * per-group completion estimates live in a lazy priority heap with
//!   generation counters — stale entries are discarded on pop;
//! * one pending engine wakeup (epoch-checked) tracks the heap top.
//!
//! Events that only touch a single-ION path now cost O(groups on that
//! ION's resources), not O(all groups).

use crate::sim::engine::Engine;
use crate::util::units::SimTime;
use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, HashMap};

/// Index of a registered resource.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ResourceId(pub u32);

/// Identifier of an in-flight flow (for cancellation / failure injection).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FlowId(pub u64);

/// A shared capacity: a link, a server NIC, or an aggregate cap.
#[derive(Debug, Clone)]
pub struct Resource {
    /// Human-readable name (diagnostics).
    pub name: String,
    /// Capacity in bytes/second.
    pub cap: f64,
    /// Current load = number of flows crossing this resource.
    load: u64,
}

/// Completion callback invoked when a flow finishes.
pub type Callback<W> = Box<dyn FnOnce(&mut Engine<W>, &mut W)>;

/// World types that embed a [`FlowNet`] implement this so the net can
/// reschedule itself from event context.
pub trait HasFlowNet: Sized + 'static {
    /// Access the embedded flow network.
    fn flownet(&mut self) -> &mut FlowNet<Self>;
}

/// Completion-tolerance in bytes: absorbs f64 accumulation error so a
/// flow scheduled to finish "now" actually pops.
const EPS_BYTES: f64 = 0.5;

struct Member<W> {
    id: FlowId,
    bytes: f64,
    cb: Callback<W>,
}

/// Ordered key: virtual finish work (bit-cast non-negative f64) + flow id
/// for tie-breaking. Bit-casting preserves order for non-negative floats.
type FinishKey = (u64, u64);

fn finish_key(virtual_finish: f64, id: FlowId) -> FinishKey {
    debug_assert!(virtual_finish >= 0.0);
    (virtual_finish.to_bits(), id.0)
}

struct Group<W> {
    path: Box<[ResourceId]>,
    /// Per-flow rate ceiling independent of resource shares (models e.g.
    /// a FUSE per-client cap without one resource per node).
    rate_cap: f64,
    /// Per-flow rate, bytes/sec (valid since `last_update`).
    rate: f64,
    /// Attained service per flow since group creation, bytes, integrated
    /// up to `last_update`.
    attained: f64,
    /// Instant `attained`/`rate` were last reconciled.
    last_update: SimTime,
    /// Slot-reuse generation (matches `FlowNet::slot_gen[slot]`).
    gen: u64,
    /// Earliest live heap entry registered for this group
    /// ([`SimTime::NEVER`] = none); estimates later than this are not
    /// pushed — the registered entry fires early and self-corrects.
    registered: SimTime,
    members: BTreeMap<FinishKey, Member<W>>,
}

impl<W> Group<W> {
    fn first_finish(&self) -> Option<f64> {
        self.members.keys().next().map(|&(bits, _)| f64::from_bits(bits))
    }

    /// Integrate attained service up to `now`.
    fn touch(&mut self, now: SimTime) {
        debug_assert!(now >= self.last_update);
        let dt = (now - self.last_update).as_secs_f64();
        if dt > 0.0 {
            if !self.members.is_empty() && self.rate.is_finite() {
                self.attained += self.rate * dt;
            }
            self.last_update = now;
        }
    }

    /// Projected completion instant of the earliest member (post-touch).
    fn next_completion(&self, now: SimTime) -> Option<SimTime> {
        let first = self.first_finish()?;
        let need = (first - self.attained).max(0.0);
        let dt = if self.rate.is_infinite() { 0.0 } else { need / self.rate };
        Some(now + SimTime::from_secs_f64(dt).max(SimTime(1)))
    }
}

/// The fluid flow network. Embed one in your simulation world and
/// implement [`HasFlowNet`].
pub struct FlowNet<W> {
    resources: Vec<Resource>,
    /// Per-resource list of group slots crossing it (stale entries are
    /// pruned lazily during repricing).
    resource_groups: Vec<Vec<usize>>,
    /// Stable group slots.
    groups: Vec<Option<Group<W>>>,
    free_slots: Vec<usize>,
    /// (path signature, rate-cap bits) -> slot.
    group_index: HashMap<(Box<[ResourceId]>, u64), usize>,
    /// flow id -> (slot, finish key) for cancellation.
    flow_index: HashMap<u64, (usize, FinishKey)>,
    /// Lazy completion heap: (time, slot, slot-gen); stale entries are
    /// skipped on pop. Entries may fire *early* (a rate drop moved the
    /// real completion later); the wakeup then reprices just that group.
    completions: BinaryHeap<Reverse<(SimTime, usize, u64)>>,
    /// Slot-reuse generations.
    slot_gen: Vec<u64>,
    next_flow: u64,
    /// Wakeup token: stale engine events are ignored.
    epoch: u64,
    /// Instant of the currently scheduled wakeup (None = none pending).
    scheduled_at: Option<SimTime>,
    // --- counters ---
    bytes_completed: f64,
    flows_completed: u64,
    flows_cancelled: u64,
    active: usize,
}

impl<W: HasFlowNet> Default for FlowNet<W> {
    fn default() -> Self {
        Self::new()
    }
}

impl<W: HasFlowNet> FlowNet<W> {
    /// Empty network.
    pub fn new() -> Self {
        FlowNet {
            resources: Vec::new(),
            resource_groups: Vec::new(),
            groups: Vec::new(),
            free_slots: Vec::new(),
            slot_gen: Vec::new(),
            group_index: HashMap::new(),
            flow_index: HashMap::new(),
            completions: BinaryHeap::new(),
            next_flow: 0,
            epoch: 0,
            scheduled_at: None,
            bytes_completed: 0.0,
            flows_completed: 0,
            flows_cancelled: 0,
            active: 0,
        }
    }

    /// Register a shared resource with capacity in bytes/sec.
    pub fn add_resource(&mut self, name: impl Into<String>, cap: f64) -> ResourceId {
        assert!(cap > 0.0, "resource capacity must be positive");
        let id = ResourceId(self.resources.len() as u32);
        self.resources.push(Resource { name: name.into(), cap, load: 0 });
        self.resource_groups.push(Vec::new());
        id
    }

    /// Look at a resource (diagnostics / tests).
    pub fn resource(&self, id: ResourceId) -> &Resource {
        &self.resources[id.0 as usize]
    }

    /// Change a capacity mid-simulation (degradation / failure injection).
    pub fn set_capacity(engine: &mut Engine<W>, world: &mut W, id: ResourceId, cap: f64) {
        assert!(cap > 0.0);
        let now = engine.now();
        let net = world.flownet();
        net.resources[id.0 as usize].cap = cap;
        net.reprice_resource(id, now);
        net.ensure_wakeup(engine);
    }

    /// Number of flows currently in flight.
    pub fn active_flows(&self) -> usize {
        self.active
    }

    /// Completed-flow counter.
    pub fn flows_completed(&self) -> u64 {
        self.flows_completed
    }

    /// Cancelled-flow counter.
    pub fn flows_cancelled(&self) -> u64 {
        self.flows_cancelled
    }

    /// Total bytes moved by completed flows.
    pub fn bytes_completed(&self) -> f64 {
        self.bytes_completed
    }

    /// Start a flow of `bytes` over `path`; `cb` fires on completion.
    pub fn start(
        engine: &mut Engine<W>,
        world: &mut W,
        path: &[ResourceId],
        bytes: u64,
        cb: impl FnOnce(&mut Engine<W>, &mut W) + 'static,
    ) -> FlowId {
        Self::start_capped(engine, world, path, bytes, f64::INFINITY, cb)
    }

    /// Start a flow whose rate is additionally capped at `rate_cap`
    /// bytes/sec regardless of resource shares (per-client NIC / FUSE
    /// ceilings without per-node resources).
    pub fn start_capped(
        engine: &mut Engine<W>,
        world: &mut W,
        path: &[ResourceId],
        bytes: u64,
        rate_cap: f64,
        cb: impl FnOnce(&mut Engine<W>, &mut W) + 'static,
    ) -> FlowId {
        assert!(!path.is_empty(), "flow needs at least one resource");
        assert!(rate_cap > 0.0, "rate cap must be positive");
        let now = engine.now();
        let net = world.flownet();
        let id = net.insert(path, bytes.max(1) as f64, rate_cap, Box::new(cb), now);
        net.ensure_wakeup(engine);
        id
    }

    /// Cancel an in-flight flow (its callback is dropped, not invoked).
    /// Returns false if the flow already completed.
    pub fn cancel(engine: &mut Engine<W>, world: &mut W, id: FlowId) -> bool {
        let now = engine.now();
        let net = world.flownet();
        let Some((slot, key)) = net.flow_index.remove(&id.0) else {
            return false;
        };
        let group = net.groups[slot].as_mut().expect("flow_index points at live group");
        group.touch(now);
        let removed = group.members.remove(&key).is_some();
        debug_assert!(removed, "flow_index out of sync");
        net.active -= 1;
        net.flows_cancelled += 1;
        let path: Box<[ResourceId]> = group.path.clone();
        net.release_load_and_maybe_gc(slot, &path, now);
        net.ensure_wakeup(engine);
        true
    }

    // ---- internals ----

    fn insert(
        &mut self,
        path: &[ResourceId],
        bytes: f64,
        rate_cap: f64,
        cb: Callback<W>,
        now: SimTime,
    ) -> FlowId {
        let id = FlowId(self.next_flow);
        self.next_flow += 1;
        let key = (Box::<[ResourceId]>::from(path), rate_cap.to_bits());
        let slot = match self.group_index.get(&key) {
            Some(&s) => s,
            None => {
                let slot = match self.free_slots.pop() {
                    Some(s) => s,
                    None => {
                        self.groups.push(None);
                        self.slot_gen.push(0);
                        self.groups.len() - 1
                    }
                };
                self.slot_gen[slot] += 1;
                self.groups[slot] = Some(Group {
                    path: key.0.clone(),
                    rate_cap,
                    rate: rate_cap,
                    attained: 0.0,
                    last_update: now,
                    gen: self.slot_gen[slot],
                    registered: SimTime::NEVER,
                    members: BTreeMap::new(),
                });
                for &r in path {
                    self.resource_groups[r.0 as usize].push(slot);
                }
                self.group_index.insert(key, slot);
                slot
            }
        };
        {
            let group = self.groups[slot].as_mut().unwrap();
            group.touch(now);
            let fkey = finish_key(group.attained + bytes, id);
            group.members.insert(fkey, Member { id, bytes, cb });
            self.flow_index.insert(id.0, (slot, fkey));
        }
        self.active += 1;
        // Load rises on every path resource; reprice all groups they touch
        // (including this one).
        for &r in path {
            self.resources[r.0 as usize].load += 1;
        }
        for &r in path {
            self.reprice_resource(r, now);
        }
        id
    }

    /// Reprice every live group crossing `r` (pruning stale slot entries):
    /// integrate attained service at the old rate, recompute the rate from
    /// current loads, bump the generation, push a fresh completion
    /// estimate.
    fn reprice_resource(&mut self, r: ResourceId, now: SimTime) {
        let mut list = std::mem::take(&mut self.resource_groups[r.0 as usize]);
        list.retain(|&slot| {
            let Some(group) = self.groups[slot].as_mut() else {
                return false; // group gone; prune
            };
            if !group.path.contains(&r) {
                return false; // slot was reused by a different group
            }
            group.touch(now);
            let mut rate = group.rate_cap;
            for &pr in group.path.iter() {
                let res = &self.resources[pr.0 as usize];
                debug_assert!(res.load > 0 || group.members.is_empty());
                if res.load > 0 {
                    rate = rate.min(res.cap / res.load as f64);
                }
            }
            group.rate = rate;
            // Push only ESTIMATES THAT MOVED EARLIER: a later real
            // completion is covered by the already-registered entry
            // firing early and self-correcting. This bounds heap growth
            // to O(rate-increase events) instead of O(reprices) — the
            // §Perf fix for global-resource workloads.
            if let Some(at) = group.next_completion(now) {
                if at < group.registered {
                    group.registered = at;
                    self.completions.push(Reverse((at, slot, group.gen)));
                }
            }
            true
        });
        self.resource_groups[r.0 as usize] = list;
    }

    /// Drop loads for a departing flow and GC its group if empty.
    fn release_load_and_maybe_gc(&mut self, slot: usize, path: &[ResourceId], now: SimTime) {
        for &r in path {
            self.resources[r.0 as usize].load -= 1;
        }
        let empty = self.groups[slot].as_ref().map(|g| g.members.is_empty()).unwrap_or(false);
        if empty {
            let g = self.groups[slot].take().unwrap();
            self.group_index.remove(&(g.path.clone(), g.rate_cap.to_bits()));
            self.free_slots.push(slot);
        }
        for &r in path {
            self.reprice_resource(r, now);
        }
    }

    /// Earliest *valid* completion estimate, discarding entries whose
    /// group slot was freed or reused.
    fn peek_next(&mut self) -> Option<SimTime> {
        while let Some(&Reverse((at, slot, gen))) = self.completions.peek() {
            match self.groups[slot].as_ref() {
                Some(g) if g.gen == gen && !g.members.is_empty() => return Some(at),
                _ => {
                    self.completions.pop();
                }
            }
        }
        None
    }

    /// Make sure an engine wakeup is pending at (or before) the earliest
    /// completion.
    fn ensure_wakeup(&mut self, engine: &mut Engine<W>) {
        let Some(at) = self.peek_next() else {
            return;
        };
        let at = at.max(engine.now() + SimTime(1));
        if let Some(t) = self.scheduled_at {
            if t <= at {
                return; // an early-enough wakeup is already pending
            }
        }
        self.epoch += 1;
        self.scheduled_at = Some(at);
        let epoch = self.epoch;
        engine.schedule_at(at, move |e, w| Self::wakeup(e, w, epoch));
    }

    fn wakeup(engine: &mut Engine<W>, world: &mut W, epoch: u64) {
        let now = engine.now();
        {
            let net = world.flownet();
            if epoch != net.epoch {
                return; // superseded by a newer wakeup
            }
            net.scheduled_at = None;
        }
        // Pop every flow due by `now` (bounded borrow), then run the
        // callbacks (which may start new flows / touch the world freely).
        let mut done: Vec<Callback<W>> = Vec::new();
        {
            let net = world.flownet();
            loop {
                let Some(at) = net.peek_next() else { break };
                if at > now {
                    break;
                }
                let Reverse((entry_at, slot, _)) = net.completions.pop().unwrap();
                // Pop all members of this group that are due.
                let path: Box<[ResourceId]> = {
                    let g = net.groups[slot].as_mut().unwrap();
                    if g.registered == entry_at {
                        g.registered = SimTime::NEVER;
                    }
                    g.path.clone()
                };
                let mut departures = 0u32;
                {
                    let g = net.groups[slot].as_mut().unwrap();
                    g.touch(now);
                    while let Some(first) = g.first_finish() {
                        if first <= g.attained + EPS_BYTES {
                            let (&key, _) = g.members.iter().next().unwrap();
                            let member = g.members.remove(&key).unwrap();
                            net.flow_index.remove(&member.id.0);
                            net.flows_completed += 1;
                            net.bytes_completed += member.bytes;
                            done.push(member.cb);
                            departures += 1;
                        } else {
                            break;
                        }
                    }
                }
                if departures > 0 {
                    net.active -= departures as usize;
                    for _ in 1..departures {
                        // release_load handles one departure's load; the
                        // first is handled below. Decrement the extras.
                        for &r in path.iter() {
                            net.resources[r.0 as usize].load -= 1;
                        }
                    }
                    net.release_load_and_maybe_gc(slot, &path, now);
                } else {
                    // Early fire (the rate dropped after this estimate
                    // was registered): push the corrected estimate.
                    let g = net.groups[slot].as_mut().unwrap();
                    if let Some(at) = g.next_completion(now) {
                        if at < g.registered {
                            g.registered = at;
                            let gen = g.gen;
                            net.completions.push(Reverse((at, slot, gen)));
                        }
                    }
                }
            }
        }
        for cb in done {
            cb(engine, world);
        }
        let net = world.flownet();
        net.ensure_wakeup(engine);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::{mbps, mib, SimTime};
    use std::cell::RefCell;
    use std::rc::Rc;

    struct World {
        net: FlowNet<World>,
        done: Rc<RefCell<Vec<(f64, &'static str)>>>,
    }

    impl HasFlowNet for World {
        fn flownet(&mut self) -> &mut FlowNet<World> {
            &mut self.net
        }
    }

    fn world() -> (Engine<World>, World) {
        (
            Engine::new().with_limit(1_000_000),
            World { net: FlowNet::new(), done: Rc::new(RefCell::new(Vec::new())) },
        )
    }

    fn mark(
        done: &Rc<RefCell<Vec<(f64, &'static str)>>>,
        name: &'static str,
    ) -> impl FnOnce(&mut Engine<World>, &mut World) {
        let done = done.clone();
        move |e, _| done.borrow_mut().push((e.now().as_secs_f64(), name))
    }

    #[test]
    fn single_flow_takes_bytes_over_cap() {
        let (mut eng, mut w) = world();
        let link = w.net.add_resource("link", mbps(100));
        let done = w.done.clone();
        FlowNet::start(&mut eng, &mut w, &[link], mib(100), mark(&done, "a"));
        eng.run(&mut w);
        let log = done.borrow();
        assert_eq!(log.len(), 1);
        assert!((log[0].0 - 1.0).abs() < 1e-6, "100MiB @ 100MiB/s should take 1s, took {}", log[0].0);
    }

    #[test]
    fn two_flows_share_fairly() {
        let (mut eng, mut w) = world();
        let link = w.net.add_resource("link", mbps(100));
        let done = w.done.clone();
        FlowNet::start(&mut eng, &mut w, &[link], mib(100), mark(&done, "a"));
        FlowNet::start(&mut eng, &mut w, &[link], mib(100), mark(&done, "b"));
        eng.run(&mut w);
        let log = done.borrow();
        // Both share 100 MiB/s -> 50 each -> both complete at t=2.
        assert_eq!(log.len(), 2);
        assert!((log[0].0 - 2.0).abs() < 1e-6, "{log:?}");
        assert!((log[1].0 - 2.0).abs() < 1e-6, "{log:?}");
    }

    #[test]
    fn late_joiner_slows_first_flow() {
        let (mut eng, mut w) = world();
        let link = w.net.add_resource("link", mbps(100));
        let done = w.done.clone();
        FlowNet::start(&mut eng, &mut w, &[link], mib(100), mark(&done, "first"));
        let d2 = done.clone();
        eng.schedule(SimTime::from_secs_f64(0.5), move |e, w| {
            let cb = mark(&d2, "second");
            FlowNet::start(e, w, &[ResourceId(0)], mib(100), cb);
        });
        eng.run(&mut w);
        let log = done.borrow();
        // first: 50MiB by 0.5s, then shares -> 1s more at 50 -> done 1.5;
        // second: 50MiB by 1.5 at 50, then 50 at 100 -> 2.0.
        assert!((log[0].0 - 1.5).abs() < 1e-6, "{log:?}");
        assert_eq!(log[0].1, "first");
        assert!((log[1].0 - 2.0).abs() < 1e-6, "{log:?}");
    }

    #[test]
    fn bottleneck_is_min_over_path() {
        let (mut eng, mut w) = world();
        let fast = w.net.add_resource("fast", mbps(1000));
        let slow = w.net.add_resource("slow", mbps(10));
        let done = w.done.clone();
        FlowNet::start(&mut eng, &mut w, &[fast, slow], mib(100), mark(&done, "a"));
        eng.run(&mut w);
        assert!((done.borrow()[0].0 - 10.0).abs() < 1e-5);
    }

    #[test]
    fn disjoint_paths_do_not_interfere() {
        let (mut eng, mut w) = world();
        let l1 = w.net.add_resource("l1", mbps(100));
        let l2 = w.net.add_resource("l2", mbps(100));
        let done = w.done.clone();
        FlowNet::start(&mut eng, &mut w, &[l1], mib(100), mark(&done, "a"));
        FlowNet::start(&mut eng, &mut w, &[l2], mib(100), mark(&done, "b"));
        eng.run(&mut w);
        for (t, _) in done.borrow().iter() {
            assert!((t - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn many_flows_different_sizes_complete_in_size_order() {
        let (mut eng, mut w) = world();
        let link = w.net.add_resource("link", mbps(100));
        let order = Rc::new(RefCell::new(Vec::new()));
        for (i, size) in [3u64, 1, 2].into_iter().enumerate() {
            let order = order.clone();
            FlowNet::start(&mut eng, &mut w, &[link], mib(size), move |_, _| {
                order.borrow_mut().push(i);
            });
        }
        eng.run(&mut w);
        assert_eq!(*order.borrow(), vec![1, 2, 0]);
        assert_eq!(w.net.flows_completed(), 3);
        assert_eq!(w.net.active_flows(), 0);
    }

    #[test]
    fn cancel_prevents_callback_and_frees_capacity() {
        let (mut eng, mut w) = world();
        let link = w.net.add_resource("link", mbps(100));
        let done = w.done.clone();
        let victim = FlowNet::start(&mut eng, &mut w, &[link], mib(100), mark(&done, "victim"));
        FlowNet::start(&mut eng, &mut w, &[link], mib(100), mark(&done, "kept"));
        eng.schedule(SimTime::from_millis(1), move |e, w| {
            assert!(FlowNet::cancel(e, w, victim));
        });
        eng.run(&mut w);
        let log = done.borrow();
        assert_eq!(log.len(), 1);
        assert_eq!(log[0].1, "kept");
        assert!(log[0].0 < 1.01, "{log:?}");
        assert_eq!(w.net.flows_cancelled(), 1);
    }

    #[test]
    fn cancel_after_completion_returns_false() {
        let (mut eng, mut w) = world();
        let link = w.net.add_resource("link", mbps(100));
        let id = FlowNet::start(&mut eng, &mut w, &[link], mib(1), |_, _| {});
        eng.run(&mut w);
        assert!(!FlowNet::cancel(&mut eng, &mut w, id));
    }

    #[test]
    fn chained_flows_from_callbacks() {
        let (mut eng, mut w) = world();
        let link = w.net.add_resource("link", mbps(100));
        let done = w.done.clone();
        let d = done.clone();
        FlowNet::start(&mut eng, &mut w, &[link], mib(50), move |e, w| {
            let cb = mark(&d, "second");
            FlowNet::start(e, w, &[ResourceId(0)], mib(50), cb);
        });
        eng.run(&mut w);
        let log = done.borrow();
        assert_eq!(log.len(), 1);
        assert!((log[0].0 - 1.0).abs() < 1e-5, "{log:?}");
    }

    #[test]
    fn capacity_change_reshapes_completion() {
        let (mut eng, mut w) = world();
        let link = w.net.add_resource("link", mbps(100));
        let done = w.done.clone();
        FlowNet::start(&mut eng, &mut w, &[link], mib(100), mark(&done, "a"));
        eng.schedule(SimTime::from_secs_f64(0.5), move |e, w| {
            FlowNet::set_capacity(e, w, ResourceId(0), mbps(50));
        });
        eng.run(&mut w);
        // 50MiB in first 0.5s, remaining 50MiB at 50MiB/s = 1s -> t=1.5.
        assert!((done.borrow()[0].0 - 1.5).abs() < 1e-5);
    }

    #[test]
    fn group_scaling_many_symmetric_flows() {
        let (mut eng, mut w) = world();
        let link = w.net.add_resource("link", mbps(1000));
        let count = Rc::new(RefCell::new(0));
        for _ in 0..1000 {
            let c = count.clone();
            FlowNet::start(&mut eng, &mut w, &[link], mib(1), move |_, _| {
                *c.borrow_mut() += 1;
            });
        }
        eng.run(&mut w);
        assert_eq!(*count.borrow(), 1000);
        // 1000 MiB total at 1000MiB/s -> all finish at t=1.
        assert!((eng.now().as_secs_f64() - 1.0).abs() < 1e-3);
    }

    #[test]
    fn slot_reuse_after_gc_is_safe() {
        // Create a group, drain it (slot freed), then create a different
        // group that reuses the slot while the old resource list still
        // mentions it — the stale entry must be pruned, not repriced.
        let (mut eng, mut w) = world();
        let a = w.net.add_resource("a", mbps(100));
        let b = w.net.add_resource("b", mbps(100));
        let done = w.done.clone();
        FlowNet::start(&mut eng, &mut w, &[a], mib(50), mark(&done, "on-a"));
        eng.run(&mut w);
        assert_eq!(w.net.active_flows(), 0);
        // New group on b likely reuses the freed slot.
        FlowNet::start(&mut eng, &mut w, &[b], mib(50), mark(&done, "on-b"));
        // And another flow on a again (fresh group on a).
        FlowNet::start(&mut eng, &mut w, &[a], mib(50), mark(&done, "on-a2"));
        eng.run(&mut w);
        let log = done.borrow();
        assert_eq!(log.len(), 3);
        // b and a2 ran concurrently on disjoint links: both ~0.5s after
        // their start (which was at t=0.5).
        assert!((log[1].0 - 1.0).abs() < 1e-5, "{log:?}");
        assert!((log[2].0 - 1.0).abs() < 1e-5, "{log:?}");
    }

    #[test]
    fn interleaved_sizes_and_joins_converge() {
        // Stress determinism + accounting under heavy churn.
        let (mut eng, mut w) = world();
        let link = w.net.add_resource("link", mbps(100));
        let count = Rc::new(RefCell::new(0u32));
        for i in 0..200u64 {
            let c = count.clone();
            let delay = SimTime::from_millis(i * 7 % 50);
            eng.schedule(delay, move |e, w| {
                let c = c.clone();
                FlowNet::start_capped(e, w, &[ResourceId(0)], mib(1 + i % 5), mbps(30) , move |_, _| {
                    *c.borrow_mut() += 1;
                });
            });
        }
        eng.run(&mut w);
        let _ = link;
        assert_eq!(*count.borrow(), 200);
        assert_eq!(w.net.active_flows(), 0);
        let total: u64 = (0..200u64).map(|i| mib(1 + i % 5)).sum();
        assert!((w.net.bytes_completed() - total as f64).abs() < 1.0);
    }
}
