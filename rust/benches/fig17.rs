//! Figure 17 (+ §6.3 text): the DOCK6 molecular-docking workflow.
//!
//! Paper anchors, 15K tasks on 8K processors:
//!   total 2140 s (GPFS) vs 1412 s (CIO);
//!   stage 1 ≈ 1.06×, stage 2 = 11.7× (694 s → 59 s), stage 3 ≈ 1.5×.
//! Large run (pass `-- --large`), 135K tasks on 96K processors, stage 1
//! only: 1981 s (GPFS) vs 1772 s (CIO) = 1.12× — compute-bound, as the
//! paper expects.
//!
//! Regenerate: `cargo bench --bench fig17` (add `-- --large` for §6.3's
//! 96K-processor run).

#[path = "common/mod.rs"]
mod common;

use cio::cio::archive::Compression;
use cio::cio::collector::Policy;
use cio::cio::fault::RetryPolicy;
use cio::cio::local::LocalLayout;
use cio::cio::local_stage::{
    task_output_name, StageExec, StageInput, StageRunner, StageRunnerConfig,
};
use cio::cio::stage::StageGraph;
use cio::config::ClusterConfig;
use cio::sim::cluster::IoMode;
use cio::util::units::{kib, mib, SimTime};
use cio::workload::dock::{run_comparison, DockWorkflow};

/// Real-bytes routed read-mix sweep: with many small IFS groups most
/// stage-2 reads cross group boundaries and are served by torus-neighbor
/// transfers (plus follow-up hits on the pulled copy); with one big
/// group every read is an IFS hit. The `routed` column counts transfers
/// the retention directory steered to a *non-producing* replica — load
/// the producer never had to serve — and `producer` the rest. GFS round
/// trips appear only when no group retains the archive — with ample
/// retention the central store drops out of the steady state entirely,
/// the paper's §5.3 point.
fn read_mix_sweep() {
    let nodes = 8u32;
    let tasks = 16u32;
    println!("--- stage-2 read-tier mix vs cn_per_ifs (real bytes, {nodes} nodes) ---");
    println!(
        "{:>10} {:>6} {:>8} {:>7} {:>9} {:>8} {:>9} {:>6} {:>7} {:>8} {:>8} {:>7} {:>6} {:>7} {:>7}",
        "cn_per_ifs", "groups", "ifs_hit", "routed", "producer", "gfs", "fallback", "hit%",
        "retries", "rerouted", "degraded", "corrupt", "hedged", "repair", "scrubs"
    );
    for cn in [1u32, 2, 4, 8] {
        let root =
            std::env::temp_dir().join(format!("cio-fig17-mix-{}-{cn}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let layout = LocalLayout::create(&root, nodes, cn).unwrap();
        let graph = StageGraph::chain(&["produce", "gather"]);
        let config = StageRunnerConfig {
            policy: Policy {
                max_delay: SimTime::from_secs(3600),
                max_data: 2048,
                min_free_space: 0,
            },
            compression: Compression::None,
            cache_capacity: mib(64),
            neighbor_limit: mib(64),
            fill_chunk_bytes: kib(64),
            threads: 4,
            retry: RetryPolicy::default(),
            faults: None,
            repair: None,
        };
        let mut runner = StageRunner::new(layout, graph, config);
        let produce =
            |t: u32, _in: &StageInput<'_>| -> anyhow::Result<Vec<u8>> { Ok(vec![t as u8; 4096]) };
        let gather = move |_t: u32, input: &StageInput<'_>| -> anyhow::Result<Vec<u8>> {
            // Every gather task reads every produce output: the all-to-all
            // that makes cross-group traffic unavoidable.
            let mut sum = 0u64;
            for t in 0..tasks {
                let (bytes, _) = input.read_member(&task_output_name(0, "produce", t))?;
                anyhow::ensure!(bytes == vec![t as u8; 4096], "task {t} bytes corrupt");
                sum += bytes.len() as u64;
            }
            Ok(sum.to_le_bytes().to_vec())
        };
        let report = runner
            .run(&[StageExec { tasks, run: &produce }, StageExec { tasks, run: &gather }])
            .expect("read-mix workflow");
        let s = &report.stages[1];
        let total = (s.ifs_hits + s.neighbor_transfers + s.gfs_misses).max(1);
        println!(
            "{:>10} {:>6} {:>8} {:>7} {:>9} {:>8} {:>9} {:>5.0}% {:>7} {:>8} {:>8} {:>7} {:>6} {:>7} {:>7}",
            cn,
            runner.layout().ifs_groups(),
            s.ifs_hits,
            s.routed_transfers,
            s.producer_transfers,
            s.gfs_misses,
            // The previously invisible eviction-race GFS retries: real
            // central-store traffic the tier counters cannot see.
            s.fallback_reads,
            100.0 * s.ifs_hits as f64 / total as f64,
            // PR-6 fault-chain columns: zero on a healthy run — printed
            // so a faulty one is visible at a glance.
            s.retries,
            s.rerouted_fills,
            s.degraded_reads,
            // PR-8 integrity columns: checksum mismatches caught on
            // arrival and hedged second fills — both zero on a healthy
            // uncontended run.
            s.corruption_detected,
            s.hedged_fills,
            // PR-10 self-healing columns: background repair pushes and
            // scheduled scrub passes — zero with no repair config.
            s.repair_pushes,
            s.scrub_cycles
        );
        drop(runner);
        let _ = std::fs::remove_dir_all(&root);
    }
}

fn main() {
    let args = common::args();
    let cfg = ClusterConfig::bgp(8192);
    let report = run_comparison(&cfg, 15_360).expect("dock comparison");
    common::footer(&report);
    read_mix_sweep();

    if args.has("large") && !common::fast() {
        println!("--- §6.3 large run: 135K tasks on 96K processors (stage 1 only) ---");
        let cfg = ClusterConfig::bgp(98_304);
        let wf = DockWorkflow { tasks: 135_168, ..Default::default() };
        let gpfs = wf.run(&cfg, IoMode::Gpfs);
        let cio = wf.run(&cfg, IoMode::Cio);
        let mut large = cio::metrics::Report::new("§6.3 large run (stage 1)");
        large.push("GPFS stage1", 1981.0, gpfs.stage1_s, "s");
        large.push("CIO stage1", 1772.0, cio.stage1_s, "s");
        large.push("speedup", 1.12, gpfs.stage1_s / cio.stage1_s, "x");
        common::footer(&large);
    }
}
